"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (
    attractive_forces_edges, binary_search_perplexity, build_quadtree,
    morton_encode, perplexity_of, sort_points_by_code, span_radius, summarize,
)
from repro.core import exact
from repro.core.morton import morton_decode_cell
from repro.core.repulsive import bh_repulsion_sorted

SETTINGS = dict(max_examples=15, deadline=None)


def finite_points(min_n=2, max_n=120):
    return hnp.arrays(
        np.float32,
        st.tuples(st.integers(min_n, max_n), st.just(2)),
        elements=st.floats(-100, 100, width=32, allow_nan=False, allow_infinity=False),
    )


@given(y=finite_points())
@settings(**SETTINGS)
def test_morton_roundtrip_cells(y):
    yj = jnp.asarray(y)
    cent, r = span_radius(yj)
    codes = morton_encode(yj, cent, r)
    cx, cy = morton_decode_cell(codes, level=16)
    # decoded integer cells must equal direct quantization
    y_root = np.asarray(cent) - float(r)
    scale = (2.0**15) / float(r)
    q = np.clip(((y - y_root) * scale), 0, 2**16 - 1).astype(np.uint32)
    assert (np.asarray(cx) == q[:, 0]).all()
    assert (np.asarray(cy) == q[:, 1]).all()


@given(y=finite_points())
@settings(**SETTINGS)
def test_quadtree_laminar_and_partition(y):
    yj = jnp.asarray(y)
    n = y.shape[0]
    cent, r = span_radius(yj)
    codes = morton_encode(yj, cent, r)
    cs, ys, _ = sort_points_by_code(yj, codes)
    tree = build_quadtree(cs)
    nn = int(tree.n_nodes)
    start = np.asarray(tree.start)[:nn]
    end = np.asarray(tree.end)[:nn]
    skip = np.asarray(tree.skip)[:nn]
    assert 1 <= nn <= 2 * n
    assert start[0] == 0 and end[0] == n
    assert (start < end).all()
    # laminar: any two ranges are nested or disjoint
    for k in range(1, min(nn, 40)):
        a = (start[k], end[k])
        b = (start[k - 1], end[k - 1])
        nested = (b[0] <= a[0] and a[1] <= b[1]) or (a[0] <= b[0] and b[1] <= a[1])
        disjoint = a[1] <= b[0] or b[1] <= a[0]
        assert nested or disjoint
    # skip pointers are strictly forward and range-consistent
    ks = np.arange(nn)
    assert (skip > ks).all()
    valid = skip < nn
    assert (start[skip[valid]] >= end[valid]).all()


@given(y=finite_points(min_n=3))
@settings(**SETTINGS)
def test_exact_repulsion_newton_third_law(y):
    f, z = exact.exact_repulsion(jnp.asarray(y))
    assert float(z) >= 0
    np.testing.assert_allclose(np.asarray(f).sum(0), 0.0, atol=1e-3)


@given(y=finite_points(min_n=4, max_n=80))
@settings(**SETTINGS)
def test_bh_matches_exact_at_theta_zero(y):
    # dedup: coincident points are fine but make relative comparison noisy
    yj = jnp.asarray(y)
    cent, r = span_radius(yj)
    codes = morton_encode(yj, cent, r)
    cs, ys, perm = sort_points_by_code(yj, codes)
    tree = build_quadtree(cs)
    summ = summarize(tree, ys, r)
    rep = bh_repulsion_sorted(ys, tree, summ, 0.0)
    f_ex, z_ex = exact.exact_repulsion(ys)
    np.testing.assert_allclose(float(jnp.sum(rep.z_per_point)), float(z_ex), rtol=5e-3, atol=1e-4)
    # float32 prefix-sum noise scales with coordinate magnitude
    atol = 1e-5 * (1.0 + float(np.abs(y).max()))
    np.testing.assert_allclose(np.asarray(rep.force), np.asarray(f_ex), rtol=2e-2, atol=atol)


@given(
    n=st.integers(8, 64),
    k=st.integers(2, 7),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_attractive_edges_antisymmetry(n, k, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, n, size=n * k), jnp.int32)
    dst = jnp.asarray((rng.integers(1, n, size=n * k) + np.asarray(src)) % n, jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, size=n * k).astype(np.float32))
    f, _ = attractive_forces_edges(y, src, dst, w)
    np.testing.assert_allclose(np.asarray(f).sum(0), 0.0, atol=1e-3)


@given(
    n=st.integers(4, 60),
    k=st.integers(3, 16),
    perp=st.floats(2.0, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_bsp_reaches_target_perplexity(n, k, perp, seed):
    perp = min(perp, k * 0.9)
    rng = np.random.default_rng(seed)
    d2 = jnp.asarray(np.sort(rng.uniform(0.01, 10, size=(n, k)), axis=1).astype(np.float32))
    cond_p, beta = binary_search_perplexity(d2, perp)
    got = np.asarray(perplexity_of(cond_p))
    np.testing.assert_allclose(got, perp, rtol=5e-2)
    assert (np.asarray(beta) > 0).all()


@given(y=finite_points(min_n=10, max_n=100), shift=st.floats(-50, 50))
@settings(**SETTINGS)
def test_bh_translation_invariance(y, shift):
    """BH repulsive forces are invariant to translating the embedding."""
    def forces(yy):
        yj = jnp.asarray(yy)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, perm = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs)
        summ = summarize(tree, ys, r)
        rep = bh_repulsion_sorted(ys, tree, summ, 0.5)
        out = np.zeros_like(yy)
        out[np.asarray(perm)] = np.asarray(rep.force)
        return out

    f0 = forces(y)
    f1 = forces(y + np.float32(shift))
    # degenerate duplicate clusters amplify one-ulp COM noise by the cluster
    # count, so the absolute tolerance scales with N * |y| * eps
    atol = max(5e-4, 2e-7 * (1.0 + float(np.abs(y).max()) + abs(shift)) * y.shape[0])
    np.testing.assert_allclose(f0, f1, rtol=5e-2, atol=atol)
