"""HLO-text cost analyzer vs known ground truth (incl. loop multiplication —
the thing XLA's own cost_analysis gets wrong for scans)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    res = analyze_hlo(_hlo(lambda x, y: x @ y, a, b))
    want = 2 * 128 * 256 * 64
    assert want <= res["flops"] <= want * 1.2, res["flops"]


def test_scan_multiplies_flops():
    a = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    res = analyze_hlo(_hlo(f, a))
    want = 10 * 2 * 128**3
    assert want * 0.9 <= res["flops"] <= want * 1.3, res["flops"]


def test_nested_scan_trips():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ a, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    res = analyze_hlo(_hlo(f, a))
    want = 20 * 2 * 64**3
    assert want * 0.9 <= res["flops"] <= want * 1.5, res["flops"]


def test_bytes_scale_with_loop():
    x = jnp.zeros((1024, 1024), jnp.float32)

    def f(v):
        def body(c, _):
            return c * 2.0 + 1.0, None
        out, _ = jax.lax.scan(body, v, None, length=7)
        return out

    res = analyze_hlo(_hlo(f, x))
    # each iteration reads+writes ~4MB x2 ops (may fuse to one)
    per_iter = 1024 * 1024 * 4
    assert res["bytes"] >= 7 * 2 * per_iter * 0.8, res["bytes"]


def test_elementwise_flops_counted():
    x = jnp.zeros((1000,), jnp.float32)
    res = analyze_hlo(_hlo(lambda v: jnp.exp(v) + v * 2.0, x))
    assert 2000 <= res["flops"] <= 10000, res["flops"]
