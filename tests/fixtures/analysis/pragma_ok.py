"""Fixture: the same RT102 hazard as retrace_bad, waived by pragma."""
import jax


def build_and_call(y):
    @jax.jit  # repro-lint: disable=RT102
    def inner(z):
        return z + y
    return inner(y)
