"""Fixture kernels with deliberately broken BlockSpecs.

Exposes ``kernel_cases()`` for ``python -m repro.analysis --kernels-from``
(and direct use from tests): each case trips exactly one KC2xx check.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def vmem_blowout(x):
    """No specs at all: the whole 64 MB operand is one resident block."""
    return pl.pallas_call(
        _copy,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def oob_index_map(x):
    """Input index map walks one block past the end of the operand."""
    return pl.pallas_call(
        _copy,
        grid=(4,),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i + 1, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((512, 128), jnp.float32),
        interpret=True,
    )(x)


def ragged_tiles(x):
    """Block height 100 does not divide the 320-row operand."""
    return pl.pallas_call(
        _copy,
        grid=(3,),
        in_specs=[pl.BlockSpec((100, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((100, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((320, 128), jnp.float32),
        interpret=True,
    )(x)


def uncovered_output(x):
    """Grid of 2 writes half the 4-block output; the rest stays garbage."""
    return pl.pallas_call(
        _copy,
        grid=(2,),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((512, 128), jnp.float32),
        interpret=True,
    )(x)


def kernel_cases():
    s = jax.ShapeDtypeStruct
    f32 = jnp.float32
    yield "vmem_blowout", vmem_blowout, (s((4096, 4096), f32),), {}
    yield "oob_index_map", oob_index_map, (s((512, 128), f32),), {}
    yield "ragged_tiles", ragged_tiles, (s((320, 128), f32),), {}
    yield "uncovered_output", uncovered_output, (s((512, 128), f32),), {}
