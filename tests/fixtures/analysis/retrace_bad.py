"""Fixture: one violation per RT1xx code (scanned by tests, never imported)."""
import functools
import time

import jax
import numpy as np


@jax.jit
def host_sync_item(x):
    return x.item()                      # RT101: host sync under jit


@jax.jit
def host_sync_cast(x):
    return float(x) + np.asarray(x)      # RT101 x2: cast + materialize


@functools.partial(jax.jit, static_argnames=("opts",))
def unhashable_static(x, opts: dict = {}):   # RT103: dict-valued static
    return x


@jax.jit
def trace_time_clock(x):
    return x * time.time()               # RT104: constant baked at trace


def build_and_call(y):
    @jax.jit                             # RT102: fresh compile cache per call
    def inner(z):
        return z + y
    return inner(y)


def unattributed_sync(x):
    x.block_until_ready()                # RT105: sync outside a Tracer span
    return x
