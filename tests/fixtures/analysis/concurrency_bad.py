"""Fixture: lock-discipline violations (scanned by tests, never imported)."""
import threading


class LeakyQueue:
    """self.items is written under the lock in put() but mutated without
    it in take(); self.done is read under the lock but written outside."""

    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition()
        self.items = []
        self.done = 0

    def put(self, x):
        with self._lock:
            self.items.append(x)

    def take(self):
        return self.items.pop()          # CC301: unlocked write

    def finish(self):
        self.done += 1                   # CC301: unlocked write, read locked

    def n_done(self):
        with self._lock:
            return self.done

    def wait_any(self):
        with self._cv:
            self._cv.wait()              # CC302: no while-predicate loop
