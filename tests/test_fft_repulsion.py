"""FIt-SNE baseline (FFT-interpolation repulsion) vs the exact oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.exact import exact_repulsion
from repro.core.fft_repulsion import fft_repulsion


@pytest.mark.parametrize("n,boxes,tol", [(500, 48, 0.05), (2000, 96, 0.01)])
def test_matches_exact(n, boxes, tol):
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 5)
    f, z = fft_repulsion(y, n_boxes=boxes)
    fe, ze = exact_repulsion(y)
    assert abs(float(z) - float(ze)) / float(ze) < tol
    num = np.linalg.norm(np.asarray(f - fe), axis=1)
    den = np.linalg.norm(np.asarray(fe), axis=1) + 1e-9
    assert np.mean(num / den) < tol


def test_clustered_points():
    rng = np.random.default_rng(1)
    c = rng.normal(size=(4, 2)) * 8
    y = jnp.asarray((c[rng.integers(0, 4, 800)] +
                     rng.normal(size=(800, 2)) * 0.3).astype(np.float32))
    f, z = fft_repulsion(y, n_boxes=96)
    fe, ze = exact_repulsion(y)
    assert abs(float(z) - float(ze)) / float(ze) < 0.02
    np.testing.assert_allclose(np.asarray(f).sum(0), np.asarray(fe).sum(0),
                               rtol=0.1, atol=1e-2)
