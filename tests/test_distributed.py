"""Distributed paths on 8 forced host devices — run in a subprocess so the
main pytest process keeps its single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return out.stdout


@pytest.mark.slow
def test_distributed_bh_gradient_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tsne, similarity, bsp
        from repro.core.knn import knn
        from repro.core.distributed import distributed_bh_gradient
        mesh = jax.make_mesh((8,), ("data",))
        n, k = 512, 12
        rng = np.random.default_rng(0)
        x = rng.normal(size=(n, 10)).astype(np.float32)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = bsp.binary_search_perplexity(d2, 4.0)
        cols, vals = similarity.symmetrize_ell(idx, cond_p)
        y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        ref = tsne.bh_gradient(y, jnp.asarray(cols), jnp.asarray(vals, jnp.float32),
                               None, theta=0.5, exaggeration=2.0, depth=16, p_logp=0.0)
        got = distributed_bh_gradient(mesh, y, jnp.asarray(cols),
                                      jnp.asarray(vals, jnp.float32), 0.0,
                                      theta=0.5, exaggeration=2.0)
        np.testing.assert_allclose(np.asarray(got.grad), np.asarray(ref.grad),
                                   rtol=2e-3, atol=1e-6)
        np.testing.assert_allclose(float(got.kl), float(ref.kl), rtol=1e-3)
        print("distributed gradient OK")
    """)


def test_ring_knn_matches_local():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.knn import knn
        from repro.core.distributed import ring_knn
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(640, 16)).astype(np.float32))
        i1, d1 = knn(x, 9)
        i2, d2 = ring_knn(mesh, x, 9)
        np.testing.assert_allclose(np.sort(np.asarray(d2), 1), np.sort(np.asarray(d1), 1),
                                   rtol=1e-4, atol=1e-4)
        same = [set(np.asarray(i1)[r]) == set(np.asarray(i2)[r]) for r in range(640)]
        assert np.mean(same) > 0.99
        print("ring knn OK")
    """)


def test_sharded_approx_recall_vs_exact_ring():
    """ISSUE 10 acceptance: the candidate ring's merged top-k must reach
    recall@k >= 0.90 against the exact ring oracle at small N."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.neighbors import make_neighbor_backend, recall_at_k
        rng = np.random.default_rng(7)
        n, k = 3000, 15
        x = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
        exact = make_neighbor_backend("sharded", dict(mode="exact", shards=8))
        ref_idx, ref_d2 = exact.neighbors(x, k)
        approx = make_neighbor_backend(
            "sharded", dict(shards=8, n_trees=8, leaf_size=32, block_rows=256))
        idx, d2 = approx.neighbors(x, k)
        ii = np.asarray(idx)
        assert ii.shape == (n, k)
        assert ((ii >= 0) & (ii < n)).all(), "pad/ghost index leaked"
        assert (ii != np.arange(n)[:, None]).all(), "self returned as neighbor"
        assert all(len(set(r)) == k for r in ii), "duplicate neighbor in a row"
        r = recall_at_k(ref_idx, idx)
        assert r >= 0.90, f"recall@{k} = {r:.3f} < 0.90"
        # exact mode through the same registry entry must agree with itself
        # across a non-dividing N (zero-pad path)
        i3, _ = exact.neighbors(x[: n - 1], k)
        assert np.asarray(i3).shape == (n - 1, k)
        assert (np.asarray(i3) < n - 1).all()
        print(f"sharded approx recall OK ({r:.3f})")
    """)


def test_sharded_preprocess_multi_device():
    """Chunked preprocess on the sharded backend: same graph invariants as
    the single-device path, across 8 forced devices."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.tsne import TsneConfig, preprocess
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.normal(size=(2048, 8)).astype(np.float32))
        cfg = TsneConfig(perplexity=10.0, neighbor_method="sharded",
                         knn_shards=8, chunk_size=500)
        graph, timings = preprocess(x, cfg)
        assert timings["neighbor_method"] == "sharded"
        assert timings["chunk_size"] == 500
        vals = np.asarray(graph.p_vals)
        assert np.isfinite(vals).all() and (vals >= 0).all()
        np.testing.assert_allclose(vals.sum(), 1.0, rtol=1e-4)
        print("sharded preprocess OK")
    """)


@pytest.mark.slow
def test_sharded_pipeline_100k_smoke():
    """Large-N smoke (CI's post-artifact step): ~100k points end-to-end
    through the sharded + chunked preprocessing path on 4 forced devices,
    plus a handful of fft gradient steps."""
    code = """
        import time, jax, jax.numpy as jnp, numpy as np
        from repro.api import make_backend
        from repro.core.tsne import TsneConfig, init_state, preprocess, tsne_step
        from repro.data.datasets import make_dataset
        n = 100_000
        assert len(jax.devices()) == 4, jax.devices()
        x, _ = make_dataset("mouse_1p3m", n=n)
        cfg = TsneConfig(perplexity=30.0, neighbor_method="sharded",
                         knn_shards=4, chunk_size=25_000, method="fft")
        graph, timings = preprocess(jnp.asarray(x), cfg)
        assert timings["neighbor_method"] == "sharded"
        assert timings["chunk_size"] == 25_000
        cols = np.asarray(graph.p_cols)
        assert ((cols >= 0) & (cols < n)).all()
        vals = np.asarray(graph.p_vals)
        assert np.isfinite(vals).all()
        np.testing.assert_allclose(vals.sum(), 1.0, rtol=1e-4)
        backend = make_backend(cfg.method, cfg, n)
        state = init_state(n, cfg)
        for _ in range(3):
            state, stats = tsne_step(
                state, graph, jnp.asarray(12.0, jnp.float32),
                jnp.asarray(0.5, jnp.float32), backend=backend,
                lr=cfg.resolve_lr(n), min_gain=cfg.min_gain)
        assert np.isfinite(np.asarray(state.y)).all()
        assert np.isfinite(float(stats.kl))
        print(f"100k smoke OK  knn={timings['knn']:.0f}s "
              f"bsp={timings['bsp']:.0f}s sym={timings['symmetrize']:.0f}s")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=3600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"


def test_compressed_psum_accuracy():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.compat import shard_map
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
        exact = np.asarray(x).sum(0)
        got = shard_map(lambda v: compressed_psum(v[0], "data"),
                            mesh=mesh, in_specs=P("data"), out_specs=P(None),
                            check_vma=False)(x)
        scale = np.abs(x).max() / 127.0
        assert np.max(np.abs(np.asarray(got) - exact)) <= 8 * scale
        print("compressed psum OK")
    """)


def test_moe_ep_shard_map_matches_local():
    run_sub("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced_config
        from repro.distributed.sharding import use_mesh_rules, DEFAULT_RULES
        from repro.models.moe import init_moe, moe_block
        cfg = get_reduced_config("deepseek_v2_lite_16b")
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
        out_local, _ = moe_block(params, x, cfg)              # no mesh: local path
        with use_mesh_rules(mesh, DEFAULT_RULES):
            out_ep = jax.jit(lambda p, v: moe_block(p, v, cfg)[0])(params, x)
        np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_local),
                                   rtol=2e-4, atol=2e-5)
        print("moe ep OK")
    """)
