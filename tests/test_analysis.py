"""repro.analysis: pass correctness on fixtures, gate/baseline workflow,
and the self-check that the shipped tree is clean against the committed
baseline."""
import json
from pathlib import Path

import pytest

from repro.analysis import concurrency, findings as fmod, retrace
from repro.analysis.cli import DEFAULT_BASELINE, DEFAULT_SCAN, main
from repro.analysis.findings import Severity

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"


def scan_fixture(mod, name):
    src = (FIXTURES / name).read_text()
    return mod.scan_source(src, f"tests/fixtures/analysis/{name}")


# ------------------------------------------------------------- retrace -----
class TestRetrace:
    def test_every_code_fires(self):
        found = scan_fixture(retrace, "retrace_bad.py")
        assert {f.code for f in found} == {
            "RT101", "RT102", "RT103", "RT104", "RT105"}

    def test_locations_and_scopes(self):
        by_code = {}
        for f in scan_fixture(retrace, "retrace_bad.py"):
            by_code.setdefault(f.code, []).append(f)
        src = (FIXTURES / "retrace_bad.py").read_text().splitlines()
        item = next(f for f in by_code["RT101"] if ".item" in f.message)
        assert "x.item()" in src[item.line - 1]
        assert item.scope == "host_sync_item"
        assert len(by_code["RT101"]) == 3        # .item, float(), np.asarray
        (rt103,) = by_code["RT103"]
        assert rt103.scope == "unhashable_static"
        (rt102,) = by_code["RT102"]
        assert "inner" in rt102.message and "build_and_call" in rt102.message
        (rt105,) = by_code["RT105"]
        assert "block_until_ready" in src[rt105.line - 1]

    def test_severities(self):
        found = scan_fixture(retrace, "retrace_bad.py")
        sev = {f.code: f.severity for f in found}
        assert sev["RT101"] == Severity.ERROR
        assert sev["RT104"] == Severity.WARNING

    def test_module_level_jit_decoration_not_flagged(self):
        src = ("import jax, functools\n"
               "@functools.partial(jax.jit, static_argnames=('k',))\n"
               "def fine(x, k: int = 2):\n"
               "    return x * k\n")
        assert retrace.scan_source(src, "m.py") == []

    def test_init_jit_sanctioned(self):
        src = ("import jax\n"
               "class Engine:\n"
               "    def __init__(self, model):\n"
               "        self._step = jax.jit(model.step)\n")
        assert retrace.scan_source(src, "m.py") == []


# --------------------------------------------------------- concurrency -----
class TestConcurrency:
    def test_codes_and_sites(self):
        found = scan_fixture(concurrency, "concurrency_bad.py")
        cc301 = [f for f in found if f.code == "CC301"]
        cc302 = [f for f in found if f.code == "CC302"]
        assert {f.scope for f in cc301} == {
            "LeakyQueue.take", "LeakyQueue.finish"}
        assert len(cc302) == 1 and cc302[0].scope == "LeakyQueue.wait_any"

    def test_lockless_class_is_silent(self):
        src = ("class Plain:\n"
               "    def __init__(self):\n"
               "        self.items = []\n"
               "    def put(self, x):\n"
               "        self.items.append(x)\n")
        assert concurrency.scan_source(src, "m.py") == []

    def test_consistent_locking_is_silent(self):
        src = ("import threading\n"
               "class Good:\n"
               "    def __init__(self):\n"
               "        self._lock = threading.Lock()\n"
               "        self.items = []\n"
               "    def put(self, x):\n"
               "        with self._lock:\n"
               "            self.items.append(x)\n"
               "    def size(self):\n"
               "        with self._lock:\n"
               "            return len(self.items)\n")
        assert concurrency.scan_source(src, "m.py") == []

    def test_wait_in_while_is_silent(self):
        src = ("import threading\n"
               "class Good:\n"
               "    def __init__(self):\n"
               "        self._cv = threading.Condition()\n"
               "        self.ready = False\n"
               "    def wait_ready(self):\n"
               "        with self._cv:\n"
               "            while not self.ready:\n"
               "                self._cv.wait()\n")
        assert not [f for f in concurrency.scan_source(src, "m.py")
                    if f.code == "CC302"]


# ------------------------------------------------------------- pragmas -----
class TestPragmas:
    def test_pragma_suppresses(self):
        src = (FIXTURES / "pragma_ok.py").read_text()
        found = fmod.apply_pragmas(
            retrace.scan_source(src, "pragma_ok.py"), fmod.scan_pragmas(src))
        assert len(found) == 1 and found[0].code == "RT102"
        assert found[0].suppressed

    def test_suppressed_findings_do_not_gate(self):
        src = (FIXTURES / "pragma_ok.py").read_text()
        found = fmod.apply_pragmas(
            retrace.scan_source(src, "pragma_ok.py"), fmod.scan_pragmas(src))
        assert fmod.gate(found, {}).ok

    def test_wrong_code_does_not_suppress(self):
        src = (FIXTURES / "pragma_ok.py").read_text().replace(
            "disable=RT102", "disable=RT101")
        found = fmod.apply_pragmas(
            retrace.scan_source(src, "pragma_ok.py"), fmod.scan_pragmas(src))
        assert not found[0].suppressed


# ---------------------------------------------------- kernel contracts -----
class TestKernelContracts:
    @pytest.fixture(scope="class")
    def cases(self):
        import sys
        sys.path.insert(0, str(FIXTURES))
        try:
            import kernel_fixture_mod
            return {name: (fn, args, kwargs)
                    for name, fn, args, kwargs
                    in kernel_fixture_mod.kernel_cases()}
        finally:
            sys.path.remove(str(FIXTURES))

    @pytest.mark.parametrize("case,code", [
        ("vmem_blowout", "KC204"),
        ("oob_index_map", "KC202"),
        ("ragged_tiles", "KC201"),
        ("uncovered_output", "KC201"),
    ])
    def test_bad_blockspec_rejected(self, cases, case, code):
        from repro.analysis.kernel_contracts import check_kernel_callable
        fn, args, kwargs = cases[case]
        found = check_kernel_callable(case, fn, args, kwargs)
        assert code in {f.code for f in found}, \
            [f.format() for f in found]

    def test_registry_all_entries_clean(self):
        from repro.analysis.kernel_contracts import check_registry
        from repro.kernels.ops import kernel_registry
        assert set(kernel_registry()) == {
            "morton_encode", "pairwise_sq_dists", "attractive_ell",
            "bsp_search", "fft_spread", "fft_gather"}
        assert check_registry() == []

    def test_unreachable_pallas_is_kc200(self):
        import jax.numpy as jnp
        import jax
        from repro.analysis.kernel_contracts import check_kernel_callable
        found = check_kernel_callable(
            "plain", jnp.sin, (jax.ShapeDtypeStruct((8,), jnp.float32),))
        assert [f.code for f in found] == ["KC200"]


# ------------------------------------------------------ gate / baseline ----
class TestGateWorkflow:
    def test_fingerprints_ignore_line_numbers(self):
        src = (FIXTURES / "retrace_bad.py").read_text()
        shifted = "# padding\n# padding\n" + src
        a = fmod.fingerprints(retrace.scan_source(src, "f.py"))
        b = fmod.fingerprints(retrace.scan_source(shifted, "f.py"))
        assert set(a) == set(b)

    def test_baseline_roundtrip_and_gate(self, tmp_path):
        found = scan_fixture(retrace, "retrace_bad.py")
        assert not fmod.gate(found, {}).ok
        path = tmp_path / "baseline.json"
        fmod.save_baseline(path, fmod.fingerprints(found))
        baseline = fmod.load_baseline(path)
        result = fmod.gate(found, baseline)
        assert result.ok and not result.stale
        # fixing a finding turns its entry stale, never a failure
        fewer = [f for f in found if f.code != "RT104"]
        result = fmod.gate(fewer, baseline)
        assert result.ok and len(result.stale) == 1

    def test_gate_cli_nonzero_per_fixture_class(self, tmp_path, capsys):
        empty = str(tmp_path / "missing.json")
        rc_retrace = main([str(FIXTURES / "retrace_bad.py"),
                           "--passes", "retrace", "--gate",
                           "--baseline", empty])
        rc_conc = main([str(FIXTURES / "concurrency_bad.py"),
                        "--passes", "concurrency", "--gate",
                        "--baseline", empty])
        capsys.readouterr()
        assert rc_retrace == 1 and rc_conc == 1

    def test_gate_cli_kernel_fixture_nonzero(self, tmp_path, capsys,
                                             monkeypatch):
        monkeypatch.syspath_prepend(str(FIXTURES))
        rc = main(["--passes", "kernels",
                   "--kernels-from", "kernel_fixture_mod",
                   "--gate", "--baseline", str(tmp_path / "missing.json"),
                   str(FIXTURES)])
        capsys.readouterr()
        assert rc == 1

    def test_write_baseline_refuses_to_grow(self, tmp_path, capsys):
        path = tmp_path / "baseline.json"
        fmod.save_baseline(path, {})
        rc = main([str(FIXTURES / "retrace_bad.py"), "--passes", "retrace",
                   "--write-baseline", "--baseline", str(path)])
        capsys.readouterr()
        assert rc == 1
        assert fmod.load_baseline(path) == {}
        rc = main([str(FIXTURES / "retrace_bad.py"), "--passes", "retrace",
                   "--write-baseline", "--allow-grow",
                   "--baseline", str(path)])
        capsys.readouterr()
        assert rc == 0 and fmod.load_baseline(path)


# ----------------------------------------------------------- self-check ----
class TestShippedTree:
    def test_repo_scan_matches_committed_baseline(self, capsys):
        """The tree as shipped gates clean: AST passes over src/repro
        against ANALYSIS_BASELINE.json (kernels covered separately above)."""
        rc = main([str(DEFAULT_SCAN), "--passes", "retrace,concurrency",
                   "--gate"])
        out = capsys.readouterr().out
        assert rc == 0, out

    def test_baseline_is_empty_for_tier1_paths(self):
        baseline = fmod.load_baseline(DEFAULT_BASELINE)
        tier1 = ("src/repro/core/", "src/repro/kernels/",
                 "src/repro/embed/", "src/repro/serve/")
        offending = [fp for fp, meta in baseline.items()
                     if meta["path"].startswith(tier1)]
        assert offending == []

    def test_committed_baseline_parses(self):
        doc = json.loads(DEFAULT_BASELINE.read_text())
        assert doc["version"] == 1
