"""Substrate tests: optimizer, checkpointing, fault tolerance (bit-exact
restart), gradient compression, serving engine."""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.pipeline import TokenPipeline
from repro.distributed.compression import (
    EFState, compress_grads, init_error_feedback,
)
from repro.distributed.fault import run_with_restarts
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig, adamw_update, global_norm, init_adamw
from repro.train.trainer import Trainer, TrainerConfig


# ------------------------------------------------------------- optimizer ---
class TestOptimizer:
    def _run(self, cfg, steps=200):
        key = jax.random.PRNGKey(0)
        target = jax.random.normal(key, (8, 16))
        params = {"w": jnp.zeros((8, 16))}
        state = init_adamw(params, cfg)

        def loss_fn(p):
            return jnp.mean((p["w"] - target) ** 2)

        for _ in range(steps):
            g = jax.grad(loss_fn)(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        return float(loss_fn(params))

    def test_adamw_converges(self):
        loss = self._run(AdamWConfig(learning_rate=0.05, weight_decay=0.0, warmup_steps=1))
        assert loss < 1e-3

    def test_factored_converges(self):
        loss = self._run(AdamWConfig(learning_rate=0.05, weight_decay=0.0,
                                     warmup_steps=1, factored=True))
        assert loss < 1e-2

    def test_bf16_moments_converge(self):
        loss = self._run(AdamWConfig(learning_rate=0.05, weight_decay=0.0,
                                     warmup_steps=1, moment_dtype="bfloat16"))
        assert loss < 1e-2

    def test_global_norm_matches_naive(self):
        tree = {"a": jnp.arange(2000, dtype=jnp.float32).reshape(2, 10, 100) / 1000,
                "b": jnp.ones((7,))}
        naive = np.sqrt(sum((np.asarray(l, np.float64) ** 2).sum()
                            for l in jax.tree.leaves(tree)))
        got = float(global_norm(tree))
        np.testing.assert_allclose(got, naive, rtol=1e-5)

    def test_grad_clipping_bounds_update(self):
        cfg = AdamWConfig(learning_rate=1.0, grad_clip=1e-3, weight_decay=0.0, warmup_steps=1)
        params = {"w": jnp.zeros((4,))}
        state = init_adamw(params, cfg)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, metrics = adamw_update(g, state, params, cfg)
        assert float(metrics["grad_norm"]) > 1e5  # norm reported pre-clip


# ------------------------------------------------------------ checkpoint ---
class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
        for step in (10, 20, 30):
            mgr.save(step, jax.tree.map(lambda x: x + step, tree))
        mgr.wait()
        assert mgr.latest_step() == 30
        restored, step = mgr.restore(tree)
        assert step == 30
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]) + 30)
        assert restored["b"]["c"].dtype == jnp.bfloat16
        # keep=2 garbage-collected step 10
        assert sorted(mgr._steps()) == [20, 30]

    def test_restore_empty_raises(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        with pytest.raises(FileNotFoundError):
            mgr.restore({"a": jnp.zeros(1)})


# ------------------------------------------------------- fault tolerance ---
class TestFaultTolerance:
    def _make_trainer(self, tmp_path, fail_at=None, n_steps=8):
        cfg = get_reduced_config("deepseek_7b")
        model = build_model(cfg)
        pipe = TokenPipeline(cfg.vocab_size, global_batch=2, seq_len=16, seed=7)
        tcfg = TrainerConfig(n_steps=n_steps, ckpt_every=2, log_every=100,
                             ckpt_dir=str(tmp_path), fail_at_step=fail_at)
        return Trainer(model, pipe, tcfg, donate=False)

    @pytest.mark.slow
    def test_restart_is_bit_exact(self, tmp_path):
        # uninterrupted run
        clean = self._make_trainer(tmp_path / "clean")
        p_clean, _, steps = clean.run(seed=3)
        assert steps == 8
        # crash at step 5, supervisor restarts from checkpoint (step 4);
        # the fault is transient (one-shot), as with a real node failure
        calls = {"n": 0}

        def make():
            fail_at = 5 if calls["n"] == 0 else None
            calls["n"] += 1
            return self._make_trainer(tmp_path / "fault", fail_at=fail_at)

        p_fault, _, steps, failures = run_with_restarts(make, seed=3)
        # exactly-once failure, resumed to completion
        assert failures >= 1 and steps == 8
        for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_fault)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_pipeline_deterministic_by_step(self):
        pipe = TokenPipeline(100, 4, 8, seed=1)
        np.testing.assert_array_equal(pipe.batch(3)["tokens"], pipe.batch(3)["tokens"])
        assert not np.array_equal(pipe.batch(3)["tokens"], pipe.batch(4)["tokens"])


# ------------------------------------------------------------ compression --
class TestCompression:
    def test_quantization_error_bounded(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(256,)).astype(np.float32))}
        ef = init_error_feedback(g)
        deq, ef2 = compress_grads(g, ef)
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.5 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 1e-4
        ef = init_error_feedback({"w": g_true})
        acc = jnp.zeros_like(g_true)
        for _ in range(64):
            deq, ef = compress_grads({"w": g_true}, ef)
            acc = acc + deq["w"]
        # with EF the time-average tracks the true gradient despite coarse bins
        np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g_true),
                                   rtol=0.05, atol=1e-7)


# ----------------------------------------------------------------- serve ---
class TestServeEngine:
    def test_continuous_batching_completes_all(self):
        cfg = get_reduced_config("deepseek_7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, batch_slots=2, max_seq=64)
        for r in range(5):
            eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=4))
        done = eng.run(params)
        assert len(done) == 5
        for req in done:
            assert len(req.generated) == 4
            assert all(0 <= t < cfg.vocab_size for t in req.generated)

    def test_step_directly_after_construction(self):
        # regression: model_params used to be assigned only inside run(), so
        # step() on a fresh engine raised AttributeError
        cfg = get_reduced_config("deepseek_7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, batch_slots=2, max_seq=32, params=params)
        eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=2))
        assert eng.step() is True
        done = eng.run()                    # params already bound at init
        assert len(done) == 1 and len(done[0].generated) == 2

    def test_step_without_params_raises(self):
        cfg = get_reduced_config("deepseek_7b")
        model = build_model(cfg)
        eng = ServeEngine(model, batch_slots=1, max_seq=32)
        eng.submit(Request(rid=0, prompt=[1], max_new_tokens=1))
        with pytest.raises(RuntimeError, match="no model params"):
            eng.step()

    def test_greedy_decode_is_deterministic(self):
        cfg = get_reduced_config("rwkv6_3b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        outs = []
        for _ in range(2):
            eng = ServeEngine(model, batch_slots=1, max_seq=32)
            eng.submit(Request(rid=0, prompt=[5, 6], max_new_tokens=6))
            outs.append(eng.run(params)[0].generated)
        assert outs[0] == outs[1]
