"""Launch-layer units: cell rules, cache shardings, collective parser,
roofline math — everything that doesn't need 512 devices."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import (
    ICI_BW, collective_bytes, model_flops, roofline_terms,
)


class TestCollectiveParser:
    def test_parses_shapes_and_kinds(self):
        hlo = """
  %ag = bf16[256,4096]{1,0} all-gather(bf16[16,4096]{1,0} %x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %done = f32[8]{0} all-gather-done(%h)
  %cp = (f32[2,2]{1,0}, f32[2,2]{1,0}) collective-permute(%z), source_target_pairs={{0,1}}
"""
        out = collective_bytes(hlo)
        assert out["all-gather"] == 256 * 4096 * 2
        assert out["all-reduce"] == 1024 * 4
        assert out["collective-permute"] == 2 * 2 * 4 * 2
        assert out["total"] == sum(out[k] for k in
                                   ("all-gather", "all-reduce", "reduce-scatter",
                                    "all-to-all", "collective-permute"))

    def test_done_not_double_counted(self):
        hlo = "  %d = f32[1000]{0} all-reduce-done(%s)\n"
        assert collective_bytes(hlo)["all-reduce"] == 0


class TestRoofline:
    def test_terms_and_dominance(self):
        r = roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5, n_chips=4)
        assert abs(r["compute_s"] - 1.0) < 1e-9
        assert abs(r["memory_s"] - 2.0) < 1e-9
        assert abs(r["collective_s"] - 0.5) < 1e-9
        assert r["dominant"] == "memory"
        assert r["hlo_flops_global"] == 197e12 * 4

    def test_model_flops_train_vs_decode(self):
        cfg = get_config("deepseek_7b")
        train = model_flops(cfg, SHAPES["train_4k"], int(6.9e9))
        dec = model_flops(cfg, SHAPES["decode_32k"], int(6.9e9))
        assert train == 6.0 * 6.9e9 * 256 * 4096
        assert dec == 2.0 * 6.9e9 * 128


class TestCellRules:
    def test_long_context_batch_unshardable(self):
        from repro.launch.cell import cell_rules
        mesh = jax.make_mesh((1, 1), ("data", "model"))

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cfg = get_config("zamba2_2p7b")
        rules = cell_rules(cfg, SHAPES["long_500k"], FakeMesh())
        assert rules["batch"] is None          # batch=1 cannot shard
        assert rules["kv_seq"] == "data"       # SP takes over
        cfg2 = get_config("minitron_8b")       # kv=8 !% 16
        rules2 = cell_rules(cfg2, SHAPES["decode_32k"], FakeMesh())
        assert rules2["kv_heads"] is None
        assert rules2["kv_seq"] == "model"

    def test_train_enables_sequence_parallelism(self):
        from repro.launch.cell import cell_rules

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        cfg = get_config("deepseek_7b")
        rules = cell_rules(cfg, SHAPES["train_4k"], FakeMesh())
        assert rules["seq"] == "model"


class TestParamsShardings:
    def test_non_divisible_dims_replicated(self):
        from repro.distributed.sharding import params_shardings
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params = {"embed": {"embedding": jnp.zeros((51865, 384))},
                  "layers": {"mlp": {"w1": jnp.zeros((4, 384, 1536))}}}
        sh = params_shardings(params, mesh)
        # sizes divide a 1x1 mesh trivially; specs still structured
        assert sh["embed"]["embedding"].spec is not None
        leaves = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
        assert len(leaves) == 2
