"""Observability layer: span tracer, metrics registry, recompile probe,
export sinks, and the estimator/benchmark integration."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (
    NULL_SPAN, Counter, Gauge, Histogram, MetricsRegistry, RecompileProbe,
    Tracer, env_trace_enabled,
)


# -------------------------------------------------------------- tracer ------
class TestTracer:
    def test_span_nesting(self):
        t = Tracer()
        with t.span("fit") as fit:
            with t.span("knn") as knn:
                pass
            with t.span("gradient_descent") as gd:
                with t.span("early_exaggeration") as ee:
                    pass
        assert [s.name for s in t.spans] == \
            ["knn", "early_exaggeration", "gradient_descent", "fit"]
        assert fit.depth == 0 and fit.parent == -1
        assert knn.depth == 1 and knn.parent == fit.index
        assert gd.depth == 1 and gd.parent == fit.index
        assert ee.depth == 2 and ee.parent == gd.index

    def test_durations_and_containment(self):
        clock = iter(float(i) for i in range(100))
        t = Tracer(clock=lambda: next(clock))
        with t.span("outer"):          # t0=1
            with t.span("inner"):      # t0=2, t1=3
                pass
        outer, inner = t.last("outer"), t.last("inner")
        assert inner.duration_s == pytest.approx(1.0)
        assert outer.duration_s == pytest.approx(3.0)
        assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1
        assert t.durations() == {"outer": 3.0, "inner": 1.0}

    def test_sync_blocks_device_work(self):
        t = Tracer()
        x = jnp.ones((256, 256))
        with t.span("matmul") as sp:
            y = sp.sync(x @ x)
        assert t.last("matmul").duration_s > 0
        assert np.asarray(y).shape == (256, 256)

    def test_annotate_lands_in_attrs(self):
        t = Tracer()
        with t.span("phase", n=10) as sp:
            sp.annotate(kl=1.5)
        assert t.last("phase").attrs == {"n": 10, "kl": 1.5}

    def test_disabled_is_noop(self):
        t = Tracer(enabled=False)
        ctx = t.span("anything", n=3)
        assert ctx is NULL_SPAN              # shared singleton, no alloc
        with ctx as sp:
            sp.annotate(a=1)
            assert sp.sync(42) == 42
        assert t.spans == [] and t.durations() == {}

    def test_chrome_trace_valid_and_nested(self, tmp_path):
        t = Tracer()
        with t.span("fit"):
            with t.span("knn"):
                pass
            with t.span("bsp"):
                pass
        path = tmp_path / "trace.json"
        t.to_chrome_trace(path)
        doc = json.loads(path.read_text())   # valid JSON
        evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
        by_name = {e["name"]: e for e in evs}
        assert set(by_name) == {"fit", "knn", "bsp"}
        for e in evs:
            assert e["dur"] >= 0 and e["ts"] >= 0
            assert e["pid"] > 0
        fit, knn = by_name["fit"], by_name["knn"]
        # child interval contained in parent interval (Perfetto nesting rule)
        assert fit["ts"] <= knn["ts"]
        assert knn["ts"] + knn["dur"] <= fit["ts"] + fit["dur"] + 1e-3

    def test_jsonl_sink(self, tmp_path):
        t = Tracer()
        with t.span("a", n=1):
            with t.span("b"):
                pass
        path = tmp_path / "spans.jsonl"
        t.to_jsonl(path)
        lines = [json.loads(ln) for ln in path.read_text().splitlines()]
        assert [d["name"] for d in lines] == ["b", "a"]
        assert lines[1]["attrs"] == {"n": 1}
        assert all(d["dur"] >= 0 for d in lines)

    def test_env_gate(self, monkeypatch):
        for v, want in [("", False), ("0", False), ("false", False),
                        ("off", False), ("1", True), ("yes", True)]:
            monkeypatch.setenv("TSNE_TRACE", v)
            assert env_trace_enabled() is want
        monkeypatch.delenv("TSNE_TRACE")
        assert env_trace_enabled() is False


# ------------------------------------------------------------- metrics ------
class TestMetrics:
    def test_counter(self):
        c = Counter("x")
        c.inc()
        c.inc(4)
        assert c.value == 5

    def test_gauge_high_water(self):
        g = Gauge("depth")
        g.set(3)
        g.set(10)
        g.set(2)
        assert g.value == 2 and g.max_value == 10

    def test_histogram_percentiles(self):
        h = Histogram("lat")
        for v in range(1, 101):          # 1..100
            h.observe(v)
        assert h.count == 100 and h.min == 1 and h.max == 100
        assert h.mean == pytest.approx(50.5)
        assert h.percentile(50) == pytest.approx(50.5)
        assert h.percentile(95) == pytest.approx(95.05)
        assert h.percentile(99) == pytest.approx(99.01)
        s = h.summary()
        assert s["p50"] == pytest.approx(50.5)
        assert s["p99"] == pytest.approx(99.01)

    def test_histogram_bounded_retention(self):
        h = Histogram("lat", max_samples=16)
        for v in range(1000):
            h.observe(v)
        assert h.count == 1000 and h.max == 999      # exact aggregates
        assert len(h._samples) == 16                 # bounded reservoir
        assert h.percentile(50) >= 984 - 16          # window = recent values

    def test_empty_histogram(self):
        h = Histogram("lat")
        assert math.isnan(h.percentile(50)) and math.isnan(h.mean)
        assert h.summary() == dict(count=0)

    def test_counter_merge(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("reqs").inc(3)
        b.counter("reqs").inc(4)
        b.counter("only_b").inc(1)
        a.merge(b)
        assert a.counter("reqs").value == 7
        assert a.counter("only_b").value == 1

    def test_registry_merge_gauges_histograms(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.gauge("q").set(5)
        b.gauge("q").set(2)
        for v in (1.0, 2.0):
            a.histogram("h").observe(v)
        for v in (3.0, 4.0):
            b.histogram("h").observe(v)
        a.merge(b)
        assert a.gauge("q").value == 2 and a.gauge("q").max_value == 5
        assert a.histogram("h").count == 4 and a.histogram("h").max == 4.0

    def test_snapshot_shape(self):
        m = MetricsRegistry()
        m.counter("c").inc()
        m.gauge("g").set(7)
        m.histogram("h").observe(1.0)
        snap = m.snapshot()
        assert snap["c"] == 1
        assert snap["g"] == dict(value=7.0, max=7.0)
        assert snap["h"]["count"] == 1
        json.dumps(snap)                 # JSON-ready

    def test_get_or_create_identity(self):
        m = MetricsRegistry()
        assert m.counter("a") is m.counter("a")
        assert m.histogram("b") is m.histogram("b")


# ------------------------------------------------------ recompile probe -----
class TestRecompileProbe:
    def test_counts_distinct_traces(self):
        reg = MetricsRegistry()
        probe = RecompileProbe("f", registry=reg)

        @jax.jit
        def f(x):
            probe.record(x.shape, x.dtype.name)
            return x * 2

        f(jnp.ones(3))
        f(jnp.ones(3) * 5)               # same shape: cached, no trace
        assert probe.count == 1
        f(jnp.ones((4,)))                # new shape: one more trace
        assert probe.count == 2
        assert probe.calls >= 2
        assert reg.counter("recompiles.f").value == 2

    def test_reset(self):
        probe = RecompileProbe("g", registry=MetricsRegistry())
        probe.record((1, 2))
        probe.reset()
        assert probe.count == 0 and probe.calls == 0


# ---------------------------------------------------------- integration -----
class TestTracedFit:
    @pytest.fixture(scope="class")
    def traced_fit(self, tmp_path_factory):
        from repro.api import TSNE
        from repro.data.datasets import make_dataset

        x, _ = make_dataset("digits", n=260)
        path = tmp_path_factory.mktemp("obs") / "fit_trace.json"
        est = TSNE(perplexity=8.0, n_iter=60, kl_every=30, random_state=0,
                   trace=str(path))
        est.fit(x)
        return est, path

    def test_phase_spans_cover_pipeline(self, traced_fit):
        est, _ = traced_fit
        names = {s.name for s in est.tracer_.spans}
        assert {"fit", "knn", "bsp", "symmetrize", "gradient_descent",
                "early_exaggeration", "checkpoint"} <= names
        fit = est.tracer_.last("fit")
        for child in ("knn", "bsp", "symmetrize", "gradient_descent"):
            sp = est.tracer_.last(child)
            assert sp.parent == fit.index and sp.depth == 1
            assert sp.duration_s > 0

    def test_timings_derived_from_spans(self, traced_fit):
        est, _ = traced_fit
        d = est.tracer_.durations()
        for phase in ("knn", "bsp", "symmetrize", "gradient_descent"):
            assert est.timings_[phase] == pytest.approx(d[phase])
            assert est.timings_[phase] > 0

    def test_chrome_trace_written_and_loadable(self, traced_fit):
        _, path = traced_fit
        doc = json.loads(path.read_text())
        names = {e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"}
        assert {"fit", "knn", "bsp", "symmetrize", "gradient_descent"} <= names

    def test_fit_metrics_recorded(self, traced_fit):
        est, _ = traced_fit
        snap = est.metrics_.snapshot()
        assert snap["fit.iterations"] == est.n_iter_
        assert snap["fit.grad_norm"]["count"] >= 1
        assert snap["fit.grad_norm"]["p95"] > 0

    def test_untraced_fit_has_timings_but_no_tracer(self):
        from repro.api import TSNE
        from repro.data.datasets import make_dataset

        x, _ = make_dataset("digits", n=200)
        est = TSNE(perplexity=6.0, n_iter=30, kl_every=30, random_state=0)
        est.fit(x)
        assert est.tracer_ is None
        for phase in ("knn", "bsp", "symmetrize", "gradient_descent"):
            assert est.timings_[phase] > 0


class TestBenchArtifact:
    def test_write_bench_json_phases_and_git(self, tmp_path, monkeypatch):
        from benchmarks import common

        monkeypatch.setattr(common, "ROWS", [("bench_a", 12.5, "")])
        monkeypatch.setattr(common, "PHASES", {})
        common.record_phases("e2e_digits", dict(
            knn=0.5, bsp=0.25, symmetrize=0.1, gradient_descent=1.5,
            neighbor_method="exact",
        ))
        common.record_phases("skipped", None)     # no-op
        path = common.write_bench_json(
            tmp_path, benches=["e2e"], argv=["--quick"], wall_s=3.0)
        doc = json.loads(path.read_text())
        assert doc["phases"]["e2e_digits"]["gradient_descent"] == 1.5
        assert "skipped" not in doc["phases"]
        assert doc["results"][0]["name"] == "bench_a"
        # this repo is a git checkout: commit provenance must be present
        assert len(doc["git"]["commit"]) == 40
        assert isinstance(doc["git"]["dirty"], bool)

    def test_unknown_bench_name_exits_nonzero(self):
        import subprocess
        import sys
        import pathlib

        root = pathlib.Path(__file__).resolve().parent.parent
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--bench", "step",
             "--no-json"],
            cwd=root, capture_output=True, text=True,
            env=dict(PYTHONPATH="src", PATH="/usr/bin:/bin:/usr/local/bin"),
        )
        assert proc.returncode != 0
        assert "unknown bench name" in proc.stderr
