"""Core BH t-SNE correctness: every step validated against the exact oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DEFAULT_DEPTH, attractive_forces_edges, attractive_forces_ell,
    bh_gradient, binary_search_perplexity, build_quadtree, knn,
    morton_encode, perplexity_of, sort_points_by_code, span_radius, summarize,
)
from repro.core import exact, similarity
from repro.core.bsp import binary_search_perplexity as bsp_search
from repro.core.repulsive import bh_repulsion_sorted
from repro.core.tsne import TsneConfig, run_tsne


def make_points(n, seed=0, clusters=4, dim=2, std=0.2):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clusters, dim)) * 3.0
    lab = rng.integers(0, clusters, size=n)
    return (centers[lab] + rng.normal(size=(n, dim)) * std).astype(np.float32), lab


# ---------------------------------------------------------------- morton ----
class TestMorton:
    def test_known_example_from_paper(self):
        # paper fig. 2: dim0 = 3 (011b), dim1 = 7 (111b) -> morton 101111b = 47
        from repro.core.morton import expand_bits_u32
        mx = int(expand_bits_u32(jnp.uint32(3)))
        my = int(expand_bits_u32(jnp.uint32(7)))
        assert mx | (my << 1) == 47

    def test_encode_monotone_along_z_order(self):
        # points on a 4x4 grid follow the Z curve ordering of fig. 2
        depth = 2
        xs, ys = np.meshgrid(np.arange(4), np.arange(4), indexing="ij")
        pts = np.stack([xs.ravel(), ys.ravel()], -1).astype(np.float32) + 0.5
        cent = jnp.asarray([2.0, 2.0])
        r = jnp.asarray(2.0)
        codes = np.asarray(morton_encode(jnp.asarray(pts), cent, r, depth=depth))
        expect = np.zeros(16, np.uint32)
        for i, (x, y) in enumerate(pts):
            xi, yi = int(x), int(y)
            code = 0
            for b in range(2):
                code |= ((xi >> b) & 1) << (2 * b)
                code |= ((yi >> b) & 1) << (2 * b + 1)
            expect[i] = code
        assert (codes == expect).all()

    def test_locality(self):
        y, _ = make_points(512, seed=1)
        cent, r = span_radius(jnp.asarray(y))
        codes = morton_encode(jnp.asarray(y), cent, r)
        order = np.argsort(np.asarray(codes))
        ys = y[order]
        # consecutive points in Z order should be close on average
        dz = np.linalg.norm(np.diff(ys, axis=0), axis=1).mean()
        rng = np.random.default_rng(0)
        drand = np.linalg.norm(ys[rng.permutation(512)][:-1] - ys[rng.permutation(512)][1:], axis=1).mean()
        assert dz < 0.5 * drand


# -------------------------------------------------------------- quadtree ----
class TestQuadtree:
    @pytest.mark.parametrize("n", [1, 2, 7, 64, 500])
    @pytest.mark.parametrize("compress", [True, False])
    def test_tree_invariants(self, n, compress):
        y, _ = make_points(n, seed=n)
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, perm = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs, compress=compress)
        nn = int(tree.n_nodes)
        cap = 2 * n + 1 if compress else 17 * n + 1
        assert 1 <= nn <= cap - 1
        start = np.asarray(tree.start)[:nn]
        end = np.asarray(tree.end)[:nn]
        level = np.asarray(tree.level)[:nn]
        skip = np.asarray(tree.skip)[:nn]
        # root covers everything
        assert start[0] == 0 and end[0] == n
        # DFS pre-order: starts non-decreasing; ranges laminar
        assert (np.diff(start) >= 0).all()
        for k in range(nn):
            assert 0 <= start[k] < end[k] <= n
            # skip points to first node at/after our end
            assert skip[k] <= nn
            if skip[k] < nn:
                assert start[skip[k]] >= end[k]
            # children immediately follow and are contained
            if skip[k] != k + 1 and k + 1 < nn:
                assert start[k + 1] >= start[k] and end[k + 1] <= end[k]
                assert level[k + 1] > level[k]

    def test_children_partition_parent(self):
        n = 300
        y, _ = make_points(n, seed=3)
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, _ = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs)
        nn = int(tree.n_nodes)
        start = np.asarray(tree.start)[:nn]
        end = np.asarray(tree.end)[:nn]
        skip = np.asarray(tree.skip)[:nn]
        for k in range(nn):
            if skip[k] == k + 1:
                continue  # leaf
            # walk direct children via skip pointers: they partition [start, end)
            c = k + 1
            covered = start[k]
            while c < nn and start[c] < end[k]:
                assert start[c] == covered
                covered = end[c]
                c = skip[c]
            assert covered == end[k]

    def test_compressed_node_count_bound(self):
        n = 1000
        y, _ = make_points(n, seed=7)
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, _, _ = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs)
        assert int(tree.n_nodes) <= 2 * n - 1

    def test_duplicate_points(self):
        y = np.zeros((16, 2), np.float32)
        y[8:] = 1.0
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, _ = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs)
        nn = int(tree.n_nodes)
        counts = np.asarray(tree.end - tree.start)[:nn]
        leaves = np.asarray(tree.is_leaf)[:nn]
        # two max-depth leaves of 8 coincident points each + root
        assert sorted(counts[leaves].tolist()) == [8, 8]


# -------------------------------------------------------------- summarize ---
class TestSummarize:
    def test_com_matches_bruteforce(self):
        n = 200
        y, _ = make_points(n, seed=5)
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, _ = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs)
        summ = summarize(tree, ys, r)
        nn = int(tree.n_nodes)
        ysn = np.asarray(ys)
        for k in range(0, nn, 7):
            s, e = int(tree.start[k]), int(tree.end[k])
            np.testing.assert_allclose(
                np.asarray(summ.com[k]), ysn[s:e].mean(0), rtol=1e-4, atol=2e-5
            )
            assert float(summ.count[k]) == e - s


# -------------------------------------------------------------- repulsive ---
class TestRepulsive:
    def _bh_forces(self, y, theta):
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, perm = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs)
        summ = summarize(tree, ys, r)
        rep = bh_repulsion_sorted(ys, tree, summ, theta)
        inv = np.empty(y.shape[0], np.int64)
        inv[np.asarray(perm)] = np.arange(y.shape[0])
        return np.asarray(rep.force)[inv], float(jnp.sum(rep.z_per_point))

    def test_theta_zero_is_exact(self):
        y, _ = make_points(150, seed=11)
        f_bh, z_bh = self._bh_forces(y, theta=0.0)
        f_ex, z_ex = exact.exact_repulsion(jnp.asarray(y))
        np.testing.assert_allclose(z_bh, float(z_ex), rtol=1e-4)
        np.testing.assert_allclose(f_bh, np.asarray(f_ex), rtol=2e-3, atol=1e-5)

    @pytest.mark.parametrize("theta", [0.2, 0.5, 0.8])
    def test_bh_approximation_quality(self, theta):
        y, _ = make_points(400, seed=13)
        f_bh, z_bh = self._bh_forces(y, theta)
        f_ex, z_ex = exact.exact_repulsion(jnp.asarray(y))
        f_ex = np.asarray(f_ex)
        rel_z = abs(z_bh - float(z_ex)) / float(z_ex)
        assert rel_z < 0.02 * max(theta, 0.1)
        denom = np.linalg.norm(f_ex, axis=1) + 1e-8
        rel_f = np.linalg.norm(f_bh - f_ex, axis=1) / denom
        # BH guarantee is on aggregate field accuracy; mean relative error
        assert rel_f.mean() < 0.05

    def test_coincident_points_no_nan(self):
        y = np.zeros((32, 2), np.float32)
        f, z = self._bh_forces(y, theta=0.5)
        assert np.isfinite(f).all() and np.isfinite(z)
        np.testing.assert_allclose(f, 0.0, atol=1e-6)
        # z = sum over ordered pairs of (1+0)^-1 = n(n-1)
        np.testing.assert_allclose(z, 32 * 31, rtol=1e-5)

    def test_auto_depth_matches_exact(self):
        from repro.core.morton import auto_depth
        y, _ = make_points(400, seed=211)
        depth = auto_depth(400)
        assert 6 <= depth < 16
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r, depth=depth)
        cs, ys, perm = sort_points_by_code(yj, codes)
        tree = build_quadtree(cs, depth=depth)
        summ = summarize(tree, ys, r)
        rep = bh_repulsion_sorted(ys, tree, summ, 0.0)
        f_ex, z_ex = exact.exact_repulsion(ys)
        np.testing.assert_allclose(float(jnp.sum(rep.z_per_point)), float(z_ex), rtol=1e-3)
        # finite depth merges co-cell points: assert aggregate accuracy
        err = np.linalg.norm(np.asarray(rep.force) - np.asarray(f_ex), axis=1)
        ref = np.linalg.norm(np.asarray(f_ex), axis=1) + 1e-8
        assert np.mean(err / ref) < 0.02
        assert np.quantile(err / ref, 0.99) < 0.2

    def test_uncompressed_tree_same_forces(self):
        y, _ = make_points(200, seed=17)
        yj = jnp.asarray(y)
        cent, r = span_radius(yj)
        codes = morton_encode(yj, cent, r)
        cs, ys, _ = sort_points_by_code(yj, codes)
        f = {}
        for compress in (True, False):
            tree = build_quadtree(cs, compress=compress)
            summ = summarize(tree, ys, r)
            rep = bh_repulsion_sorted(ys, tree, summ, 0.0)
            f[compress] = np.asarray(rep.force)
        np.testing.assert_allclose(f[True], f[False], rtol=1e-4, atol=1e-6)


# -------------------------------------------------------------- attractive --
class TestAttractive:
    def test_ell_vs_dense_oracle(self):
        n, k = 128, 12
        x, _ = make_points(n, seed=19, dim=8)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = bsp_search(d2, 5.0)
        sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        p_dense = similarity.dense_p_matrix(idx, cond_p)
        y, _ = make_points(n, seed=23)
        f_ell, kl_ell = attractive_forces_ell(
            jnp.asarray(y), jnp.asarray(sym_cols), jnp.asarray(sym_vals, jnp.float32)
        )
        f_ex, kl_ex = exact.exact_attraction(jnp.asarray(y), jnp.asarray(p_dense, jnp.float32))
        np.testing.assert_allclose(np.asarray(f_ell), np.asarray(f_ex), rtol=1e-4, atol=1e-7)
        np.testing.assert_allclose(float(kl_ell), float(kl_ex), rtol=1e-4)

    def test_components_vs_ell(self):
        from repro.core.attractive import attractive_forces_ell_components
        n, k = 128, 12
        x, _ = make_points(n, seed=101, dim=8)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = bsp_search(d2, 5.0)
        sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        y, _ = make_points(n, seed=103)
        f_a, kl_a = attractive_forces_ell(
            jnp.asarray(y), jnp.asarray(sym_cols), jnp.asarray(sym_vals, jnp.float32))
        f_b, kl_b = attractive_forces_ell_components(
            jnp.asarray(y), jnp.asarray(sym_cols), jnp.asarray(sym_vals, jnp.float32))
        np.testing.assert_allclose(np.asarray(f_b), np.asarray(f_a), rtol=1e-5, atol=1e-8)
        np.testing.assert_allclose(float(kl_b), float(kl_a), rtol=1e-6)

    def test_edges_vs_ell(self):
        n, k = 96, 10
        x, _ = make_points(n, seed=29, dim=6)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = bsp_search(d2, 4.0)
        sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        src, dst, w = similarity.edge_list(idx, cond_p)
        y, _ = make_points(n, seed=31)
        f_ell, kl_ell = attractive_forces_ell(
            jnp.asarray(y), jnp.asarray(sym_cols), jnp.asarray(sym_vals, jnp.float32)
        )
        f_edges, kl_edges = attractive_forces_edges(jnp.asarray(y), src, dst, w)
        np.testing.assert_allclose(np.asarray(f_edges), np.asarray(f_ell), rtol=1e-4, atol=1e-7)
        np.testing.assert_allclose(float(kl_edges), float(kl_ell), rtol=1e-4)


# --------------------------------------------------------------------- bsp --
class TestBSP:
    @pytest.mark.parametrize("perplexity", [5.0, 15.0, 30.0])
    def test_perplexity_reached(self, perplexity):
        n, k = 256, int(3 * perplexity)
        x, _ = make_points(n, seed=37, dim=10)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, beta = binary_search_perplexity(d2, perplexity)
        perp = np.asarray(perplexity_of(cond_p))
        np.testing.assert_allclose(perp, perplexity, rtol=1e-2)
        assert (np.asarray(beta) > 0).all()
        np.testing.assert_allclose(np.asarray(cond_p).sum(1), 1.0, rtol=1e-5)


# --------------------------------------------------------------------- knn --
class TestKNN:
    @pytest.mark.parametrize("n,dim,k", [(100, 4, 5), (1000, 16, 15), (257, 20, 7)])
    def test_matches_bruteforce(self, n, dim, k):
        rng = np.random.default_rng(41)
        x = rng.normal(size=(n, dim)).astype(np.float32)
        idx, d2 = knn(jnp.asarray(x), k)
        d = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
        np.fill_diagonal(d, np.inf)
        ref_idx = np.argsort(d, axis=1)[:, :k]
        ref_d = np.take_along_axis(d, ref_idx, axis=1)
        np.testing.assert_allclose(np.sort(np.asarray(d2), 1), np.sort(ref_d, 1), rtol=1e-3, atol=1e-4)
        # index sets must match (distance ties allowed)
        same = [set(np.asarray(idx)[i]) == set(ref_idx[i]) for i in range(n)]
        assert np.mean(same) > 0.99

    def test_no_self_neighbor(self):
        x = np.random.default_rng(43).normal(size=(300, 8)).astype(np.float32)
        idx, _ = knn(jnp.asarray(x), 10)
        assert not (np.asarray(idx) == np.arange(300)[:, None]).any()


# -------------------------------------------------------- full BH gradient --
class TestGradient:
    def test_bh_gradient_matches_exact(self):
        n, k, perp = 200, 24, 8.0
        x, _ = make_points(n, seed=47, dim=12)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = bsp_search(d2, perp)
        sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        p_dense = similarity.dense_p_matrix(idx, cond_p)
        y, _ = make_points(n, seed=53)
        res = bh_gradient(
            jnp.asarray(y), jnp.asarray(sym_cols), jnp.asarray(sym_vals, jnp.float32),
            None, theta=0.0, exaggeration=1.0, depth=DEFAULT_DEPTH, p_logp=0.0,
        )
        g_ex = exact.exact_gradient(jnp.asarray(y), jnp.asarray(p_dense, jnp.float32))
        np.testing.assert_allclose(np.asarray(res.grad), np.asarray(g_ex), rtol=5e-3, atol=1e-6)

    def test_kl_estimate_matches_exact(self):
        n, k, perp = 150, 15, 5.0
        x, _ = make_points(n, seed=59, dim=12)
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = bsp_search(d2, perp)
        sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        p_dense = similarity.dense_p_matrix(idx, cond_p)
        pv = sym_vals[sym_vals > 0]
        p_logp = float((pv * np.log(pv)).sum())
        y, _ = make_points(n, seed=61)
        res = bh_gradient(
            jnp.asarray(y), jnp.asarray(sym_cols), jnp.asarray(sym_vals, jnp.float32),
            None, theta=0.0, exaggeration=1.0, depth=DEFAULT_DEPTH, p_logp=p_logp,
        )
        kl_ex = exact.exact_kl(jnp.asarray(y), jnp.asarray(p_dense, jnp.float32))
        np.testing.assert_allclose(float(res.kl), float(kl_ex), rtol=1e-3)


# ------------------------------------------------------------- end-to-end ---
class TestEndToEnd:
    def test_tsne_separates_clusters(self):
        n = 600
        x, lab = make_points(n, seed=67, clusters=3, dim=20, std=0.15)
        cfg = TsneConfig(perplexity=15.0, n_iter=300, exaggeration_iters=100,
                         momentum_switch_iter=100, seed=1)
        res = run_tsne(x, cfg, kl_every=100)
        assert np.isfinite(res.y).all()
        assert np.isfinite(res.kl)
        # KL decreased over the run
        assert res.kl_history[-1, 1] <= res.kl_history[0, 1] + 1e-3
        # cluster separation: mean intra-cluster dist << inter-cluster dist
        y = res.y
        intra, inter = [], []
        for c in range(3):
            m = y[lab == c]
            intra.append(np.linalg.norm(m - m.mean(0), axis=1).mean())
        cents = np.stack([y[lab == c].mean(0) for c in range(3)])
        for i in range(3):
            for j in range(i + 1, 3):
                inter.append(np.linalg.norm(cents[i] - cents[j]))
        assert np.mean(intra) < 0.5 * np.mean(inter)

    @pytest.mark.slow
    def test_edges_impl_close_to_ell(self):
        n = 300
        x, _ = make_points(n, seed=71, clusters=3, dim=10)
        kl = {}
        for impl in ("ell", "edges"):
            cfg = TsneConfig(perplexity=10.0, n_iter=150, exaggeration_iters=50,
                             momentum_switch_iter=50, attractive_impl=impl, seed=2)
            kl[impl] = run_tsne(x, cfg, kl_every=150).kl_history[-1, 1]
        # identical forces; KL differs only by the constant-sum-p-log-p estimate
        assert abs(kl["ell"] - kl["edges"]) < 0.5
