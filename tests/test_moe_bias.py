"""Aux-free router-bias balancing: bias moves against observed load and the
loop self-balances a skewed router."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models.moe import init_moe, moe_block
from repro.train.moe_bias import update_router_bias


def test_bias_moves_against_load():
    params = {"layers": {"mlp": {"router": {"bias": jnp.zeros((4,)),
                                            "w": jnp.zeros((8, 4))}}}}
    load = jnp.asarray([2.0, 1.0, 0.5, 0.5])
    new = update_router_bias(params, load, rate=0.1)
    bias = np.asarray(new["layers"]["mlp"]["router"]["bias"])
    assert bias[0] < 0            # overloaded -> less selectable
    assert bias[2] > 0 and bias[3] > 0
    # router weights untouched
    np.testing.assert_array_equal(
        np.asarray(new["layers"]["mlp"]["router"]["w"]), 0.0)


def test_balancing_loop_reduces_skew():
    base = get_reduced_config("deepseek_v3_671b")
    cfg = dataclasses.replace(base, moe=dataclasses.replace(
        base.moe, router="sigmoid_bias", capacity_factor=8.0))
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    # mild skew on a *diverse* router (degenerate tied scores have no
    # stable equilibrium under the sign update — not the production regime)
    skew = np.zeros((cfg.d_model, cfg.moe.n_experts), np.float32)
    skew[:, 0] = 0.05
    params["router"]["w"] = params["router"]["w"] + jnp.asarray(skew)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))

    def load_of(p):
        _, aux = moe_block(p, x, cfg)
        return aux["expert_load"]

    l0 = load_of(params)
    p = params
    for _ in range(100):
        l = load_of(p)
        p = {"router": {"w": p["router"]["w"],
                        "bias": update_router_bias({"router": p["router"]}, l,
                                                   rate=0.01)["router"]["bias"]},
             "experts": p["experts"], "shared": p["shared"]}
    l1 = load_of(p)
    assert float(jnp.std(l1)) < float(jnp.std(l0)) * 0.7, (l0, l1)
