"""Out-of-sample subsystem: neighbor query indexes, TSNE.transform,
fitted-state persistence, and the continuous-batching EmbeddingService."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import TSNE, EmbeddingService, TransformConfig, TransformRequest
from repro.data.datasets import make_dataset
from repro.embed.transform import RETRACE_PROBE, transform_batch
from repro.neighbors import (
    ExactNeighbors, NNDescentNeighbors, RPForestNeighbors, build_query_index,
    recall_at_k,
)


@pytest.fixture(scope="module")
def digits_split():
    """Train/held-out split of the digits-scale planted-cluster data."""
    x, labels = make_dataset("digits", n=700)
    return (x[:600], labels[:600]), (x[600:], labels[600:])


@pytest.fixture(scope="module")
def fitted(digits_split):
    """One fitted estimator shared by the transform/service tests."""
    (train_x, _), _ = digits_split
    est = TSNE(perplexity=12.0, n_iter=250, kl_every=125, random_state=0)
    est.fit(train_x)
    return est


@pytest.fixture(scope="module")
def query_oracle():
    """Reference set + new points + exact query answer (numpy oracle)."""
    x, _ = make_dataset("digits")
    ref, new = x[:1500], x[1500:1700]
    d2 = ((new[:, None, :] - ref[None]) ** 2).sum(-1)
    ref_idx = np.argsort(d2, axis=1)[:, :15]
    return jnp.asarray(ref), jnp.asarray(new), ref_idx, d2


# ------------------------------------------------------------- query() ------
class TestQueryIndex:
    def test_exact_query_matches_oracle(self, query_oracle):
        ref, new, ref_idx, d2 = query_oracle
        idx, qd2 = ExactNeighbors().build_index(ref).query(new, 15)
        assert recall_at_k(ref_idx, np.asarray(idx)) == 1.0
        np.testing.assert_allclose(
            np.asarray(qd2), np.take_along_axis(d2, np.asarray(idx), 1),
            rtol=1e-3, atol=1e-2,
        )

    def test_rp_forest_query_recall(self, query_oracle):
        # satellite acceptance: forest query recall >= 0.9 vs exact
        ref, new, ref_idx, d2 = query_oracle
        index = RPForestNeighbors().build_index(ref)
        idx, qd2 = index.query(new, 15)
        idx = np.asarray(idx)
        assert recall_at_k(ref_idx, idx) >= 0.9
        # indices valid + distinct, distances exact for the selected
        assert ((idx >= 0) & (idx < index.n_reference)).all()
        srt = np.sort(idx, axis=1)
        assert not (srt[:, 1:] == srt[:, :-1]).any()
        np.testing.assert_allclose(
            np.asarray(qd2), np.take_along_axis(d2, idx, 1),
            rtol=1e-3, atol=1e-2,
        )

    def test_nn_descent_falls_back_to_exact(self, query_oracle):
        ref, new, ref_idx, _ = query_oracle
        index = build_query_index(NNDescentNeighbors(), ref)
        idx, _ = index.query(new, 15)
        assert recall_at_k(ref_idx, np.asarray(idx)) == 1.0

    def test_fallback_for_backend_without_index(self, query_oracle):
        class Bare:
            name = "bare"
            def neighbors(self, x, k):
                raise NotImplementedError

        ref, new, ref_idx, _ = query_oracle
        idx, _ = build_query_index(Bare(), ref).query(new, 15)
        assert recall_at_k(ref_idx, np.asarray(idx)) == 1.0

    def test_query_k_validation(self, query_oracle):
        ref, new, _, _ = query_oracle
        index = ExactNeighbors().build_index(ref[:10])
        with pytest.raises(ValueError, match="must be >= 1"):
            index.query(new, 0)
        with pytest.raises(ValueError, match="reference-set size"):
            index.query(new, 11)


# ----------------------------------------------------------- transform ------
class TestTransform:
    def test_lands_in_own_cluster(self, digits_split, fitted):
        """Held-out points land nearest their own class's fitted cluster:
        embedding-space KNN-label agreement >= the input-space baseline."""
        (train_x, train_l), (test_x, test_l) = digits_split
        y_new, stats = fitted.transform(test_x, return_stats=True)
        assert y_new.shape == (len(test_x), 2)
        assert np.isfinite(y_new).all()
        assert (stats.n_steps >= 1).all()

        def knn_label_acc(space_train, space_test):
            d2 = ((space_test[:, None, :] - space_train[None]) ** 2).sum(-1)
            votes = train_l[np.argsort(d2, axis=1)[:, :5]]
            pred = np.array([np.bincount(v).argmax() for v in votes])
            return (pred == test_l).mean()

        baseline = knn_label_acc(train_x, test_x)       # input-space 5-NN
        acc = knn_label_acc(fitted.embedding_, y_new)   # embedding-space 5-NN
        assert acc >= baseline - 0.05
        assert acc >= 0.8

    def test_no_retrace_across_batches(self, digits_split, fitted):
        # fixed-shape step: different batch sizes share one jit trace —
        # the obs recompile probe counts distinct compiled variants
        _, (test_x, _) = digits_split
        fitted.transform(test_x[:20])
        n_traces = RETRACE_PROBE.count
        fitted.transform(test_x[:7])
        fitted.transform(test_x[:33])
        assert RETRACE_PROBE.count == n_traces

    def test_transform_is_deterministic(self, digits_split, fitted):
        _, (test_x, _) = digits_split
        a = fitted.transform(test_x[:12])
        b = fitted.transform(test_x[:12])
        np.testing.assert_array_equal(a, b)

    def test_reuses_fitted_neighbor_structure(self, fitted):
        # the query index is built once and cached until the next fit
        idx1 = fitted.query_index_
        assert fitted.query_index_ is idx1
        assert idx1.n_reference == fitted.embedding_.shape[0]
        assert fitted.query_k_ == fitted.n_neighbors_

    def test_validation(self, digits_split, fitted):
        _, (test_x, _) = digits_split
        with pytest.raises(ValueError, match="not fitted"):
            TSNE().transform(test_x)
        with pytest.raises(ValueError, match="expected x_new shaped"):
            fitted.transform(test_x[:, :10])
        with pytest.raises(ValueError, match="expected x_new shaped"):
            fitted.transform(test_x[0])

    def test_transform_config_overrides(self, digits_split, fitted):
        _, (test_x, _) = digits_split
        cfg = TransformConfig(n_iter=5, check_every=5, batch_size=16)
        y, stats = fitted.transform(test_x[:8], transform_config=cfg,
                                    return_stats=True)
        assert (stats.n_steps <= 5).all()
        assert np.isfinite(y).all()


# --------------------------------------------------------- persistence ------
class TestSaveLoad:
    def test_roundtrip_serves_identical_transforms(self, digits_split, fitted,
                                                   tmp_path):
        _, (test_x, _) = digits_split
        path = tmp_path / "digits_model.npz"
        fitted.save(path)
        loaded = TSNE.load(path)
        np.testing.assert_array_equal(loaded.embedding_, fitted.embedding_)
        assert loaded.kl_divergence_ == pytest.approx(fitted.kl_divergence_)
        assert loaded.n_neighbors_ == fitted.n_neighbors_
        assert loaded.perplexity == fitted.perplexity
        # the persisted sparse-P graph survives
        g, g0 = loaded.neighbor_graph_, fitted.neighbor_graph_
        np.testing.assert_array_equal(np.asarray(g.p_cols),
                                      np.asarray(g0.p_cols))
        np.testing.assert_allclose(np.asarray(g.p_vals),
                                   np.asarray(g0.p_vals), rtol=1e-6)
        # and the loaded model answers transform queries identically
        np.testing.assert_allclose(loaded.transform(test_x[:10]),
                                   fitted.transform(test_x[:10]), atol=1e-5)

    def test_unfitted_save_raises(self, tmp_path):
        with pytest.raises(ValueError, match="not fitted"):
            TSNE().save(tmp_path / "nope.npz")


# -------------------------------------------------------------- service -----
class TestEmbeddingService:
    def test_drains_32_requests_through_8_slots(self, digits_split, fitted):
        # tentpole acceptance: 32-request queue, <= 8 slots, all completed,
        # per-request stats reported
        _, (test_x, _) = digits_split
        service = EmbeddingService(slots=8, max_k=48)
        service.add_model("digits", fitted)
        for i in range(32):
            service.submit(TransformRequest(rid=i, dataset="digits",
                                            x=test_x[i]))
        done = service.run()
        assert len(done) == 32
        for req in done:
            assert req.done and req.y is not None
            assert np.isfinite(req.y).all()
            assert req.n_steps >= 1
            assert req.latency_s > 0 and req.service_s > 0
            assert np.isfinite(req.grad_norm)
        s = service.stats()
        assert s["completed"] == 32 and s["queued"] == 0
        assert s["steps_mean"] >= 1 and s["latency_s_p50"] > 0
        # histogram-backed percentiles are ordered and finite
        assert s["latency_s_p50"] <= s["latency_s_p95"] <= s["latency_s_p99"]
        assert s["latency_s_p99"] <= s["latency_s_max"]
        # gauges saw the load: 32 queued requests through at most 8 lanes,
        # all 8 occupied at some tick, and telemetry counted every retirement
        assert s["slot_occupancy_max"] == 8
        assert 1 <= s["queue_depth_max"] <= 32
        assert service.metrics.counter("service.completed").value == 32
        assert service.metrics.counter("service.ticks").value == s["ticks"]
        assert service.metrics.histogram("service.latency_s").count == 32
        # drained pool: both gauges ended at zero
        assert service.metrics.gauge("service.queue_depth").value == 0
        assert service.metrics.gauge("service.slot_occupancy").value == 0
        # service results agree with the batch transform path
        y_batch = fitted.transform(test_x[:32])
        y_srv = np.stack([r.y for r in sorted(done, key=lambda r: r.rid)])
        assert np.linalg.norm(y_srv - y_batch, axis=1).max() < 0.1

    def test_multi_dataset_cache(self, digits_split, fitted):
        _, (test_x, _) = digits_split
        x2, _ = make_dataset("mnist", n=160)
        service = EmbeddingService(slots=4, max_k=48)
        service.add_model("digits", fitted)
        service.fit_dataset("mnist_small", x2[:140], perplexity=8.0,
                            n_iter=80, kl_every=40, random_state=1)
        assert service.models() == ("digits", "mnist_small")
        for i in range(6):
            service.submit(TransformRequest(rid=i, dataset="digits",
                                            x=test_x[i]))
            service.submit(TransformRequest(rid=100 + i, dataset="mnist_small",
                                            x=x2[140 + i]))
        done = service.run()
        assert len(done) == 12
        assert {r.dataset for r in done} == {"digits", "mnist_small"}
        assert all(np.isfinite(r.y).all() for r in done)

    def test_submit_unknown_dataset_raises(self):
        service = EmbeddingService(slots=2)
        with pytest.raises(ValueError, match="unknown dataset"):
            service.submit(TransformRequest(rid=0, dataset="nope",
                                            x=np.zeros(4)))

    def test_unfitted_model_rejected(self):
        service = EmbeddingService(slots=2)
        with pytest.raises(ValueError, match="not fitted"):
            service.add_model("raw", TSNE())

    def test_step_on_empty_pool_is_false(self):
        assert EmbeddingService(slots=2).step() is False

    def test_load_model_from_save(self, digits_split, fitted, tmp_path):
        _, (test_x, _) = digits_split
        path = tmp_path / "m.npz"
        fitted.save(path)
        service = EmbeddingService(slots=2, max_k=48)
        service.load_model("digits", path)
        service.submit(TransformRequest(rid=0, dataset="digits", x=test_x[0]))
        done = service.run()
        assert len(done) == 1 and np.isfinite(done[0].y).all()


# ------------------------------------------------------ transform_batch -----
class TestTransformBatch:
    def test_direct_driver_padding(self, fitted, digits_split):
        # m smaller than, equal to, and not divisible by batch_size
        _, (test_x, _) = digits_split
        cfg = TransformConfig(n_iter=30, batch_size=8)
        for m in (3, 8, 11):
            y, stats = transform_batch(
                test_x[:m], fitted.query_index_, fitted.embedding_,
                k=fitted.query_k_, perplexity=fitted.perplexity, config=cfg,
            )
            assert y.shape == (m, 2) and np.isfinite(y).all()
            assert stats.n_steps.shape == (m,)
