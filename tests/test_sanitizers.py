"""Numerics sanitizers over a real (small) fit.

The static passes in ``repro.analysis`` catch structural hazards; this
test catches the numeric ones the same way ASAN catches memory bugs —
run the pipeline with every tripwire armed:

* ``jax_debug_nans`` — any NaN produced inside a jitted computation
  re-raises at the producing primitive (a silent NaN in the perplexity
  search or gradient would otherwise just propagate into the embedding);
* ``jax_numpy_rank_promotion='raise'`` — implicit broadcasting across
  ranks is an error (the classic source of silently-wrong reductions in
  [N, K]-vs-[N] arithmetic).

Slow-marked: the sanitizers force per-primitive checks, so the fit runs
well off the fast path.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")


def make_points(n, seed=0, clusters=4, dim=8, std=0.2):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clusters, dim)) * 3.0
    lab = rng.integers(0, clusters, size=n)
    return (centers[lab] + rng.normal(size=(n, dim)) * std).astype(np.float32)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["exact", "barnes_hut", "fft"])
def test_fit_under_sanitizers(method):
    from repro.api import TSNE

    x = make_points(192, seed=7, clusters=3)
    prev_nans = jax.config.jax_debug_nans
    prev_rank = jax.config.jax_numpy_rank_promotion
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_numpy_rank_promotion", "raise")
    try:
        est = TSNE(method=method, perplexity=10.0, n_iter=60, kl_every=30,
                   random_state=0)
        emb = est.fit_transform(x)
    finally:
        jax.config.update("jax_debug_nans", prev_nans)
        jax.config.update("jax_numpy_rank_promotion", prev_rank)
    assert emb.shape == (192, 2)
    assert np.isfinite(emb).all()
    assert np.isfinite(est.kl_divergence_)
