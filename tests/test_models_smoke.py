"""Per-architecture smoke tests: reduced configs, one loss/prefill/decode
step on CPU, asserting shapes and finiteness (no NaNs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import build_model

B, S = 2, 16

# the giant reduced configs still dominate suite wall time; tier-1 CI skips
# their (costlier) train-step smoke but keeps every prefill/decode check
_SLOW_TRAIN_ARCHS = {
    "deepseek_v3_671b", "deepseek_v2_lite_16b", "whisper_tiny", "zamba2_2p7b",
}
TRAIN_ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_TRAIN_ARCHS else a
    for a in ARCH_IDS
]


def make_batch(cfg, key, kind="train"):
    k1, k2, k3 = jax.random.split(key, 3)
    s_text = S - (cfg.vlm.n_patches if cfg.family == "vlm" else 0)
    extra = 1 if kind == "train" else 0
    batch = {"tokens": jax.random.randint(k1, (B, s_text + extra), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(k2, (B, cfg.vlm.n_patches, cfg.d_model), cfg.cdtype())
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(k3, (B, cfg.enc_dec.n_frames, cfg.d_model), cfg.cdtype())
    return batch


@pytest.mark.parametrize("arch", TRAIN_ARCH_PARAMS)
def test_train_step_smoke(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0
    # one gradient step moves the loss and produces finite grads
    grads, _ = jax.jit(jax.grad(model.loss_fn, has_aux=True))(params, batch)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves), f"{arch}: NaN grads"
    lr = 1e-2
    new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2, _ = jax.jit(model.loss_fn)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_smoke(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), kind="prefill")
    logits = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: prefill NaNs"

    cache = model.init_cache(B, S)
    token = jnp.zeros((B,), jnp.int32)
    pos = jnp.full((B,), 3, jnp.int32)
    dlogits, new_cache = jax.jit(model.decode_step)(params, cache, token, pos)
    assert dlogits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(dlogits, np.float32)).all(), f"{arch}: decode NaNs"
    # cache structure preserved
    jax.tree.map(lambda a, b: None, cache, new_cache)


@pytest.mark.parametrize("arch", ["deepseek_7b", "rwkv6_3b", "zamba2_2p7b", "deepseek_v2_lite_16b"])
def test_decode_matches_prefill(arch):
    """Greedy next-token from (prefill over S tokens) == (S decode steps)."""
    # path equivalence is a math property — test it in f32 (bf16 noise through
    # recurrent state otherwise dominates); for MoE, raise the capacity factor
    # so neither path drops tokens (drop policy legitimately differs between
    # a 1-token decode batch and a full prefill batch)
    cfg = dataclasses.replace(get_reduced_config(arch), compute_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)
    logits_prefill = jax.jit(model.prefill)(params, {"tokens": tokens})

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    logits = None
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t], jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(logits_prefill, np.float32),
        rtol=1e-3, atol=1e-4,
    )


def test_wkv_chunked_matches_scan():
    """Chunked-parallel WKV (hillclimb) == sequential recurrence, including
    the carried state and non-multiple sequence lengths."""
    from repro.models.rwkv import _wkv_chunked_parallel, _wkv_scan
    rng = np.random.default_rng(0)
    b, t, h, p = 2, 77, 3, 16
    mk = lambda: jnp.asarray(rng.normal(size=(b, t, h, p)).astype(np.float32))
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.05, 0.999, size=(b, t, h, p)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, p)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(b, h, p, p)).astype(np.float32))
    o1, s1 = _wkv_scan(r, k, v, w, u, s0)
    o2, s2 = _wkv_chunked_parallel(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1), rtol=1e-4, atol=1e-4)


def test_param_count_sanity():
    from repro.configs import all_configs, param_count
    expected = {  # billions, loose bands from the public model cards
        "llama3_405b": (380, 430),
        "deepseek_v3_671b": (600, 720),
        "deepseek_v2_lite_16b": (13, 19),
        "deepseek_7b": (6, 8),
        "minitron_8b": (7.5, 10),
        "phi4_mini_3p8b": (3.2, 4.6),
        "rwkv6_3b": (2.5, 3.8),
        "zamba2_2p7b": (2.2, 3.4),
        "llava_next_34b": (32, 37),
        "whisper_tiny": (0.025, 0.055),
    }
    for name, cfg in all_configs().items():
        total, active = param_count(cfg)
        lo, hi = expected[name]
        assert lo * 1e9 <= total <= hi * 1e9, f"{name}: {total/1e9:.2f}B outside [{lo},{hi}]"
        if name != "zamba2_2p7b":  # zamba2 re-applies the shared block: active > total
            assert active <= total
