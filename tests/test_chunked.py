"""Chunked preprocessing parity: the million-point streaming forms must
match the whole-array references exactly (ISSUE 10 acceptance).

* chunked perplexity search == unchunked for several chunk sizes,
  including non-dividing ones and chunk > N;
* chunked (streaming-CSR) symmetrization is *bit-identical* to the
  host-reference ELL merge;
* ``preprocess`` with ``chunk_size`` produces the same NeighborGraph as
  without, and the sharded neighbor backend slots into it on one device.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsp, similarity
from repro.core.knn import knn
from repro.core.tsne import TsneConfig, preprocess
from repro.data.datasets import make_dataset

N, K, PERP = 900, 31, 10.0

# deliberately includes dividing (300), non-dividing (257, 128), degenerate
# (1), and over-long (N + 1) chunk sizes
CHUNKS = (1, 128, 257, 300, N - 1, N + 1)


@pytest.fixture(scope="module")
def graph_inputs():
    x, _ = make_dataset("digits", n=N)
    idx, d2 = knn(jnp.asarray(x), K)
    return x, idx, d2


@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_bsp_matches_unchunked(graph_inputs, chunk):
    _, _, d2 = graph_inputs
    ref_p, ref_b = bsp.binary_search_perplexity(d2, PERP)
    cp, cb = bsp.binary_search_perplexity_chunked(d2, PERP, chunk)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(ref_p),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(np.asarray(cb), np.asarray(ref_b), rtol=1e-6)


def test_chunked_bsp_pallas_impl(graph_inputs):
    _, _, d2 = graph_inputs
    ref_p, _ = bsp.binary_search_perplexity(d2, PERP)
    cp, _ = bsp.binary_search_perplexity_chunked(d2, PERP, 257, impl="pallas")
    np.testing.assert_allclose(np.asarray(cp), np.asarray(ref_p),
                               rtol=2e-5, atol=1e-7)


def test_chunked_bsp_rejects_bad_chunk(graph_inputs):
    _, _, d2 = graph_inputs
    with pytest.raises(ValueError, match="chunk_size"):
        bsp.binary_search_perplexity_chunked(d2, PERP, 0)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_chunked_symmetrize_bit_identical(graph_inputs, chunk):
    _, idx, d2 = graph_inputs
    cond_p, _ = bsp.binary_search_perplexity(d2, PERP)
    ref_c, ref_v = similarity.symmetrize_ell(idx, np.asarray(cond_p))
    sc, sv = similarity.symmetrize_ell_chunked(idx, np.asarray(cond_p), chunk)
    assert sc.shape == ref_c.shape
    assert (sc == ref_c).all()
    assert (sv == ref_v).all()


def test_preprocess_chunked_matches_unchunked(graph_inputs):
    x, _, _ = graph_inputs
    base = dict(perplexity=PERP, neighbor_method="exact")
    g_ref, _ = preprocess(jnp.asarray(x), TsneConfig(**base))
    g_chk, timings = preprocess(
        jnp.asarray(x), TsneConfig(**base, chunk_size=257))
    assert timings["chunk_size"] == 257
    np.testing.assert_array_equal(np.asarray(g_chk.p_cols),
                                  np.asarray(g_ref.p_cols))
    np.testing.assert_allclose(np.asarray(g_chk.p_vals),
                               np.asarray(g_ref.p_vals), rtol=1e-7)
    np.testing.assert_allclose(float(g_chk.p_logp), float(g_ref.p_logp),
                               rtol=1e-6)


def test_sharded_backend_single_device(graph_inputs):
    """On one device the ring degenerates to a single local forest pass —
    the registry path must still produce a valid, high-recall graph."""
    from repro.neighbors import make_neighbor_backend, recall_at_k

    x, ref_idx, _ = graph_inputs
    nb = make_neighbor_backend(
        "sharded", dict(shards=1, n_trees=8, leaf_size=32, block_rows=256))
    idx, d2 = nb.neighbors(jnp.asarray(x), K)
    ii = np.asarray(idx)
    assert ii.shape == (N, K)
    assert ((ii >= 0) & (ii < N)).all()
    assert (ii != np.arange(N)[:, None]).all()
    assert all(len(set(r)) == K for r in ii)
    assert recall_at_k(ref_idx, idx) >= 0.90
    assert (np.asarray(d2) >= 0).all()


def test_sharded_backend_options_validate():
    from repro.neighbors import make_neighbor_backend

    with pytest.raises(ValueError, match="mode"):
        make_neighbor_backend("sharded", dict(mode="bogus"))
    nb = make_neighbor_backend("sharded", dict(shards=64))
    with pytest.raises(ValueError, match="device"):
        nb.neighbors(jnp.ones((4096, 4), jnp.float32), 8)
