"""Pipeline parallelism vs sequential reference (forced host devices)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipelined, pipeline_bubble
        mesh = jax.make_mesh((4,), ("pipe",))
        d = 16
        n_stages, n_micro, micro_b = 4, 8, 4
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(n_stages, d, d)).astype(np.float32) / np.sqrt(d))
        x = jnp.asarray(rng.normal(size=(n_micro * micro_b, d)).astype(np.float32))

        def stage(wi, h):
            return jnp.tanh(h @ wi)

        apply = pipelined(stage, mesh, n_micro=n_micro)
        got = apply(w, x)
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-6)
        assert abs(pipeline_bubble(8, 4) - 3/11) < 1e-9
        print("pipeline OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
