"""benchmarks/run.py --compare: delta computation + the regression-exit path."""
import json

import pytest

from benchmarks import common
from benchmarks import run as bench_run


def _prev_doc(results, schema=2):
    doc = dict(schema=schema, results=results)
    if schema >= 2:
        doc["git"] = dict(commit="deadbeefcafe", dirty=False)
    return doc


def _row(name, us):
    return dict(name=name, us_per_call=us, derived="")


@pytest.fixture
def rows(monkeypatch):
    """Isolate the module-global ROWS accumulator per test."""
    monkeypatch.setattr(common, "ROWS", [])
    return common.ROWS


class TestCompareRuns:
    def test_within_threshold_passes(self):
        prev = _prev_doc([_row("a", 100.0), _row("b", 200.0)])
        cur = [("a", 110.0, ""), ("b", 180.0, "")]
        lines, regressions = common.compare_runs(prev, cur, threshold=0.25)
        assert regressions == []
        assert any("+10.0%" in ln for ln in lines)

    def test_injected_regression_detected(self):
        prev = _prev_doc([_row("a", 100.0), _row("b", 200.0)])
        cur = [("a", 130.0, ""), ("b", 190.0, "")]  # a: +30% > 25%
        lines, regressions = common.compare_runs(prev, cur, threshold=0.25)
        assert len(regressions) == 1
        name, p, us, delta = regressions[0]
        assert name == "a" and p == 100.0 and us == 130.0
        assert delta == pytest.approx(0.30)
        assert any("REGRESSION" in ln for ln in lines)

    def test_speedup_never_gates(self):
        prev = _prev_doc([_row("a", 100.0)])
        _, regressions = common.compare_runs(
            prev, [("a", 10.0, "")], threshold=0.25)
        assert regressions == []

    def test_new_and_missing_benches_tolerated(self):
        prev = _prev_doc([_row("gone", 50.0)])
        lines, regressions = common.compare_runs(
            prev, [("brand_new", 999999.0, "")], threshold=0.25)
        assert regressions == []
        assert any("NEW" in ln for ln in lines)
        assert any("not run" in ln for ln in lines)

    def test_schema_1_artifacts_comparable(self):
        prev = _prev_doc([_row("a", 100.0)], schema=1)
        _, regressions = common.compare_runs(
            prev, [("a", 140.0, "")], threshold=0.25)
        assert len(regressions) == 1


class TestLoadBenchJson:
    def test_roundtrip(self, tmp_path):
        path = tmp_path / "BENCH_3.json"
        path.write_text(json.dumps(_prev_doc([_row("a", 1.0)])))
        doc = common.load_bench_json(path)
        assert doc["results"][0]["name"] == "a"

    def test_unknown_schema_rejected(self, tmp_path):
        path = tmp_path / "BENCH_9.json"
        path.write_text(json.dumps(dict(schema=99, results=[])))
        with pytest.raises(ValueError, match="unsupported BENCH schema"):
            common.load_bench_json(path)

    def test_missing_results_rejected(self, tmp_path):
        path = tmp_path / "BENCH_9.json"
        path.write_text(json.dumps(dict(schema=2)))
        with pytest.raises(ValueError, match="no results rows"):
            common.load_bench_json(path)


class TestCompareGate:
    """run_compare_gate is the exact exit path main() sys.exit()s with."""

    def test_regression_exits_nonzero(self, tmp_path, rows, capsys):
        prev_path = tmp_path / "BENCH_0.json"
        prev_path.write_text(json.dumps(_prev_doc(
            [_row("steps_x", 100.0), _row("e2e_y", 1000.0)])))
        common.emit("steps_x", 131.0)          # +31% -> regression
        common.emit("e2e_y", 1001.0)
        code = bench_run.run_compare_gate(str(prev_path), 0.25)
        assert code == 1
        err = capsys.readouterr().err
        assert "FAIL" in err and "steps_x" in err and "+31.0%" in err

    def test_clean_run_exits_zero(self, tmp_path, rows, capsys):
        prev_path = tmp_path / "BENCH_0.json"
        prev_path.write_text(json.dumps(_prev_doc([_row("steps_x", 100.0)])))
        common.emit("steps_x", 101.0)
        code = bench_run.run_compare_gate(str(prev_path), 0.25)
        assert code == 0
        assert "compare OK" in capsys.readouterr().err

    def test_threshold_is_configurable(self, tmp_path, rows):
        prev_path = tmp_path / "BENCH_0.json"
        prev_path.write_text(json.dumps(_prev_doc([_row("steps_x", 100.0)])))
        common.emit("steps_x", 110.0)          # +10%
        assert bench_run.run_compare_gate(str(prev_path), 0.25) == 0
        assert bench_run.run_compare_gate(str(prev_path), 0.05) == 1
