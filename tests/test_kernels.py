"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bsp as bsp_ref
from repro.core import fft_repulsion as fft_ref
from repro.core import morton as morton_ref
from repro.core import _pairwise as pairwise_ref
from repro.core import attractive as attractive_ref
from repro.kernels.attractive_kernel import attractive_forces_ell_pallas
from repro.kernels.bsp_kernel import binary_search_perplexity_pallas
from repro.kernels.interp_kernel import (
    gather_from_grid_pallas, spread_to_grid_pallas,
)
from repro.kernels.morton_kernel import morton_encode_pallas
from repro.kernels.pairwise_kernel import pairwise_sq_dists_pallas


@pytest.mark.parametrize("n", [1, 100, 1024, 2500])
@pytest.mark.parametrize("depth", [8, 16])
def test_morton_kernel_matches_ref(n, depth):
    rng = np.random.default_rng(n)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 10)
    cent, r = morton_ref.span_radius(y)
    ref = morton_ref.morton_encode(y, cent, r, depth=depth)
    out = morton_encode_pallas(y, cent, r, depth=depth)
    assert (np.asarray(out) == np.asarray(ref)).all()


@pytest.mark.parametrize("nq,nc,d", [(64, 64, 8), (128, 256, 20), (300, 500, 64), (1000, 777, 784)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_pairwise_kernel_matches_ref(nq, nc, d, dtype):
    rng = np.random.default_rng(nq + nc)
    q = jnp.asarray(rng.normal(size=(nq, d)), dtype)
    c = jnp.asarray(rng.normal(size=(nc, d)), dtype)
    ref = pairwise_ref.pairwise_sq_dists(q, c)
    out = pairwise_sq_dists_pallas(q, c)
    np.testing.assert_allclose(np.asarray(out), np.maximum(np.asarray(ref), 0), rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("n,w", [(10, 3), (256, 90), (1000, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_attractive_kernel_matches_ref(n, w, dtype):
    rng = np.random.default_rng(n + w)
    y = jnp.asarray(rng.normal(size=(n, 2)), dtype)
    cols = jnp.asarray(rng.integers(0, n, size=(n, w)), jnp.int32)
    vals = jnp.asarray(rng.uniform(0, 1e-3, size=(n, w)), dtype)
    f_ref, kl_ref = attractive_ref.attractive_forces_ell(y, cols, vals)
    f, kl = attractive_forces_ell_pallas(y, cols, vals)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(kl), float(kl_ref), rtol=1e-5)


@pytest.mark.parametrize("n,k", [(1, 5), (65, 20), (500, 45), (1000, 90)])
@pytest.mark.parametrize("perplexity", [8.0, 30.0])
def test_bsp_kernel_matches_ref(n, k, perplexity):
    if k > 3 * perplexity:
        k = int(3 * perplexity)
    rng = np.random.default_rng(n + k)
    d2 = jnp.asarray(np.abs(rng.normal(size=(n, k))).astype(np.float32) * 4)
    p_ref, b_ref = bsp_ref._binary_search_perplexity_xla(d2, perplexity)
    p, b = binary_search_perplexity_pallas(d2, perplexity)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_ref), rtol=1e-5)
    # and the search itself converged: realized perplexity == target
    if k > perplexity:
        realized = np.asarray(bsp_ref.perplexity_of(p))
        np.testing.assert_allclose(realized, perplexity, rtol=1e-2)


def test_bsp_dispatch_and_validation():
    rng = np.random.default_rng(3)
    d2 = jnp.asarray(np.abs(rng.normal(size=(128, 24))).astype(np.float32))
    p_x, b_x = bsp_ref.binary_search_perplexity(d2, 7.0, impl="xla")
    p_p, b_p = bsp_ref.binary_search_perplexity(d2, 7.0, impl="pallas")
    np.testing.assert_allclose(np.asarray(p_p), np.asarray(p_x),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(b_p), np.asarray(b_x), rtol=1e-5)
    with pytest.raises(ValueError, match="unknown bsp impl"):
        bsp_ref.binary_search_perplexity(d2, 7.0, impl="numba")


def _planted_interp(n_boxes=4, n_ch=3):
    """Points sitting exactly on lattice nodes: one-hot Lagrange weights, so
    spread/gather are exact integer scatters with no float ambiguity."""
    nodes = n_boxes * (fft_ref.P_ORDER - 1) + 1
    rng = np.random.default_rng(0)
    n = 40
    base = rng.integers(0, n_boxes, size=(n, 2)).astype(np.int32) * 2
    taps = rng.integers(0, fft_ref.P_ORDER, size=(n, 2))
    wx = np.zeros((n, 3), np.float32)
    wy = np.zeros((n, 3), np.float32)
    wx[np.arange(n), taps[:, 0]] = 1.0
    wy[np.arange(n), taps[:, 1]] = 1.0
    charges = rng.integers(1, 5, size=(n, n_ch)).astype(np.float32)
    return nodes, jnp.asarray(base), jnp.asarray(wx), jnp.asarray(wy), \
        jnp.asarray(charges), taps


def test_spread_kernel_exact_on_planted_grid():
    nodes, base, wx, wy, charges, taps = _planted_interp()
    expected = np.zeros((nodes, nodes, 3), np.float32)
    b = np.asarray(base)
    for i in range(b.shape[0]):
        expected[b[i, 0] + taps[i, 0], b[i, 1] + taps[i, 1]] += np.asarray(charges)[i]
    ref = fft_ref.spread_to_grid(base, wx, wy, charges, nodes)
    out = spread_to_grid_pallas(base, wx, wy, charges, nodes)
    assert (np.asarray(ref) == expected).all()
    assert (np.asarray(out) == expected).all()


def test_gather_kernel_exact_on_planted_grid():
    nodes, base, wx, wy, _charges, taps = _planted_interp()
    rng = np.random.default_rng(1)
    pot = jnp.asarray(rng.integers(-9, 9, size=(nodes, nodes, 4)).astype(np.float32))
    b = np.asarray(base)
    expected = np.asarray(pot)[b[:, 0] + taps[:, 0], b[:, 1] + taps[:, 1]]
    ref = fft_ref.gather_from_grid(pot, base, wx, wy)
    out = gather_from_grid_pallas(pot, base, wx, wy)
    assert (np.asarray(ref) == expected).all()
    assert (np.asarray(out) == expected).all()


@pytest.mark.parametrize("n,n_boxes", [(50, 16), (700, 48), (1500, 64)])
def test_interp_kernels_match_ref(n, n_boxes):
    rng = np.random.default_rng(n)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 5)
    nodes = n_boxes * (fft_ref.P_ORDER - 1) + 1
    base, wx, wy, _h = fft_ref.interp_coords(y, n_boxes)
    charges = jnp.stack([jnp.ones((n,), jnp.float32), y[:, 0], y[:, 1]], axis=1)
    g_ref = fft_ref.spread_to_grid(base, wx, wy, charges, nodes)
    g = spread_to_grid_pallas(base, wx, wy, charges, nodes)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)
    pot = jnp.asarray(rng.normal(size=(nodes, nodes, 4)).astype(np.float32))
    ph_ref = fft_ref.gather_from_grid(pot, base, wx, wy)
    ph = gather_from_grid_pallas(pot, base, wx, wy)
    np.testing.assert_allclose(np.asarray(ph), np.asarray(ph_ref),
                               rtol=1e-4, atol=1e-5)


def test_fft_repulsion_pallas_interp_matches_xla():
    rng = np.random.default_rng(9)
    y = jnp.asarray(rng.normal(size=(600, 2)).astype(np.float32) * 8)
    f_x, z_x = fft_ref.fft_repulsion(y, n_boxes=48, interp_impl="xla")
    f_p, z_p = fft_ref.fft_repulsion(y, n_boxes=48, interp_impl="pallas")
    scale = float(jnp.max(jnp.abs(f_x)))
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f_x),
                               rtol=1e-3, atol=1e-4 * scale)
    np.testing.assert_allclose(float(z_p), float(z_x), rtol=1e-4)
    with pytest.raises(ValueError, match="unknown interp impl"):
        fft_ref.fft_repulsion(y, n_boxes=48, interp_impl="cuda")


def test_kernel_registry_dispatch():
    from repro.kernels import ops
    names = ops.available_kernels()
    assert {"bsp_search", "fft_spread", "fft_gather",
            "attractive_ell", "pairwise_sq_dists", "morton_encode"} <= set(names)
    assert ops.get_kernel("bsp_search", "ref") is bsp_ref._binary_search_perplexity_xla
    assert ops.get_kernel("bsp_search", "pallas") is ops.binary_search_perplexity
    with pytest.raises(ValueError, match="unknown kernel"):
        ops.get_kernel("nope")
    with pytest.raises(ValueError, match="impl must be"):
        ops.get_kernel("bsp_search", "cuda")


def test_knn_with_pallas_pairwise_matches_xla():
    from repro.core.knn import knn
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(500, 16)).astype(np.float32))
    i1, d1 = knn(x, 10, pairwise_fn_name="xla")
    i2, d2 = knn(x, 10, pairwise_fn_name="pallas")
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)
    same = [set(np.asarray(i1)[r]) == set(np.asarray(i2)[r]) for r in range(500)]
    assert np.mean(same) > 0.99


def test_tsne_with_pallas_path_runs():
    from repro.core.tsne import TsneConfig, run_tsne
    rng = np.random.default_rng(11)
    x = rng.normal(size=(256, 10)).astype(np.float32)
    # use_pallas=True now routes the perplexity search too (bsp_impl="auto")
    cfg = TsneConfig(perplexity=8.0, n_iter=30, exaggeration_iters=10,
                     momentum_switch_iter=10, use_pallas=True, seed=3)
    res = run_tsne(x, cfg, kl_every=30)
    assert np.isfinite(res.y).all() and np.isfinite(res.kl)
    assert res.timings["bsp_impl"] == "pallas"


def test_tsne_fft_backend_with_pallas_interp_runs():
    from repro.core.tsne import TsneConfig, run_tsne
    rng = np.random.default_rng(12)
    x = rng.normal(size=(256, 10)).astype(np.float32)
    cfg = TsneConfig(perplexity=8.0, n_iter=16, exaggeration_iters=6,
                     momentum_switch_iter=6, method="fft", fft_n_boxes=16,
                     fft_interp_impl="pallas", bsp_impl="pallas", seed=3)
    res = run_tsne(x, cfg, kl_every=16)
    assert np.isfinite(res.y).all() and np.isfinite(res.kl)
    assert res.timings["bsp_impl"] == "pallas"
