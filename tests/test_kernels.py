"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import morton as morton_ref
from repro.core import _pairwise as pairwise_ref
from repro.core import attractive as attractive_ref
from repro.kernels.attractive_kernel import attractive_forces_ell_pallas
from repro.kernels.morton_kernel import morton_encode_pallas
from repro.kernels.pairwise_kernel import pairwise_sq_dists_pallas


@pytest.mark.parametrize("n", [1, 100, 1024, 2500])
@pytest.mark.parametrize("depth", [8, 16])
def test_morton_kernel_matches_ref(n, depth):
    rng = np.random.default_rng(n)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32) * 10)
    cent, r = morton_ref.span_radius(y)
    ref = morton_ref.morton_encode(y, cent, r, depth=depth)
    out = morton_encode_pallas(y, cent, r, depth=depth)
    assert (np.asarray(out) == np.asarray(ref)).all()


@pytest.mark.parametrize("nq,nc,d", [(64, 64, 8), (128, 256, 20), (300, 500, 64), (1000, 777, 784)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_pairwise_kernel_matches_ref(nq, nc, d, dtype):
    rng = np.random.default_rng(nq + nc)
    q = jnp.asarray(rng.normal(size=(nq, d)), dtype)
    c = jnp.asarray(rng.normal(size=(nc, d)), dtype)
    ref = pairwise_ref.pairwise_sq_dists(q, c)
    out = pairwise_sq_dists_pallas(q, c)
    np.testing.assert_allclose(np.asarray(out), np.maximum(np.asarray(ref), 0), rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("n,w", [(10, 3), (256, 90), (1000, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_attractive_kernel_matches_ref(n, w, dtype):
    rng = np.random.default_rng(n + w)
    y = jnp.asarray(rng.normal(size=(n, 2)), dtype)
    cols = jnp.asarray(rng.integers(0, n, size=(n, w)), jnp.int32)
    vals = jnp.asarray(rng.uniform(0, 1e-3, size=(n, w)), dtype)
    f_ref, kl_ref = attractive_ref.attractive_forces_ell(y, cols, vals)
    f, kl = attractive_forces_ell_pallas(y, cols, vals)
    np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(kl), float(kl_ref), rtol=1e-5)


def test_knn_with_pallas_pairwise_matches_xla():
    from repro.core.knn import knn
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(500, 16)).astype(np.float32))
    i1, d1 = knn(x, 10, pairwise_fn_name="xla")
    i2, d2 = knn(x, 10, pairwise_fn_name="pallas")
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4, atol=1e-4)
    same = [set(np.asarray(i1)[r]) == set(np.asarray(i2)[r]) for r in range(500)]
    assert np.mean(same) > 0.99


def test_tsne_with_pallas_path_runs():
    from repro.core.tsne import TsneConfig, run_tsne
    rng = np.random.default_rng(11)
    x = rng.normal(size=(256, 10)).astype(np.float32)
    cfg = TsneConfig(perplexity=8.0, n_iter=30, exaggeration_iters=10,
                     momentum_switch_iter=10, use_pallas=True, seed=3)
    res = run_tsne(x, cfg, kl_every=30)
    assert np.isfinite(res.y).all() and np.isfinite(res.kl)
