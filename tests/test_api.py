"""Public API surface: backend registry, backend equivalence, sklearn parity."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    TSNE, BarnesHutBackend, ExactBackend, FFTBackend, IterationStats,
    TsneConfig, available_backends, make_backend, preprocess, register_backend,
    run_tsne, unregister_backend,
)
from repro.core.tsne import DEFAULT_ATTRACTIVE_IMPL
from repro.data.datasets import make_dataset


def make_points(n, seed=0, clusters=4, dim=2, std=0.2):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clusters, dim)) * 3.0
    lab = rng.integers(0, clusters, size=n)
    return (centers[lab] + rng.normal(size=(n, dim)) * std).astype(np.float32), lab


@pytest.fixture(scope="module")
def small_graph():
    x, _ = make_points(256, seed=5, clusters=3, dim=10)
    cfg = TsneConfig(perplexity=10.0)
    graph, _ = preprocess(jnp.asarray(x), cfg)
    return cfg, graph


# ------------------------------------------------------------- registry -----
class TestRegistry:
    def test_builtins_registered(self):
        assert {"exact", "barnes_hut", "fft"} <= set(available_backends())

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError, match="unknown t-SNE method"):
            make_backend("nope", TsneConfig(), 100)
        with pytest.raises(ValueError, match="unknown t-SNE method"):
            TSNE(method="nope", perplexity=5.0).fit(make_points(64)[0])

    def test_config_flows_into_backend(self):
        cfg = TsneConfig(theta=0.3, compress_tree=False, depth="auto",
                         fft_n_boxes=96)
        bh = make_backend("barnes_hut", cfg, 4096)
        assert bh.theta == 0.3 and not bh.compress_tree
        assert isinstance(bh.depth, int) and bh.depth >= 1
        assert make_backend("fft", cfg, 4096).n_boxes == 96

    def test_custom_backend_registration(self):
        class TaggedExact(ExactBackend):
            name = "tagged_exact"

        register_backend("tagged_exact", lambda cfg, n: TaggedExact())
        try:
            assert "tagged_exact" in available_backends()
            x, _ = make_points(96, seed=9, dim=6)
            est = TSNE(method="tagged_exact", perplexity=8.0, n_iter=60,
                       kl_every=30)
            emb = est.fit_transform(x)
            assert emb.shape == (96, 2) and np.isfinite(emb).all()
        finally:
            unregister_backend("tagged_exact")
        assert "tagged_exact" not in available_backends()

    def test_attractive_impl_single_source_of_truth(self):
        # satellite: config and backend defaults must agree
        assert TsneConfig().attractive_impl == DEFAULT_ATTRACTIVE_IMPL
        assert BarnesHutBackend().attractive_impl == DEFAULT_ATTRACTIVE_IMPL
        assert FFTBackend().attractive_impl == DEFAULT_ATTRACTIVE_IMPL
        cfg = TsneConfig()
        assert make_backend("barnes_hut", cfg, 100).attractive_impl \
            == cfg.attractive_impl


# -------------------------------------------------- backend equivalence -----
class TestBackendEquivalence:
    def test_barnes_hut_theta0_matches_exact(self, small_graph):
        cfg, graph = small_graph
        y = jnp.asarray(make_points(graph.n, seed=7)[0])
        ex = ExactBackend().gradient(y, graph, 1.0)
        bh = dataclasses.replace(
            make_backend("barnes_hut", cfg, graph.n), theta=0.0
        ).gradient(y, graph, 1.0)
        np.testing.assert_allclose(np.asarray(bh.grad), np.asarray(ex.grad),
                                   rtol=5e-3, atol=1e-6)
        np.testing.assert_allclose(float(bh.kl), float(ex.kl), rtol=1e-3)
        np.testing.assert_allclose(float(bh.z), float(ex.z), rtol=1e-3)

    def test_fft_close_to_exact(self, small_graph):
        cfg, graph = small_graph
        y = jnp.asarray(make_points(graph.n, seed=7)[0])
        ex = ExactBackend().gradient(y, graph, 1.0)
        ft = FFTBackend(n_boxes=64).gradient(y, graph, 1.0)
        np.testing.assert_allclose(float(ft.z), float(ex.z), rtol=2e-2)
        np.testing.assert_allclose(float(ft.kl), float(ex.kl), rtol=2e-2)
        err = np.linalg.norm(np.asarray(ft.grad) - np.asarray(ex.grad), axis=1)
        ref = np.linalg.norm(np.asarray(ex.grad), axis=1) + 1e-8
        assert np.mean(err / ref) < 0.05

    def test_exaggeration_scales_attractive_only(self, small_graph):
        cfg, graph = small_graph
        y = jnp.asarray(make_points(graph.n, seed=7)[0])
        for backend in (ExactBackend(), make_backend("barnes_hut", cfg, graph.n),
                        FFTBackend()):
            g1 = backend.gradient(y, graph, 1.0)
            g2 = backend.gradient(y, graph, 4.0)
            # grad = 4 (exag * F_attr - F_rep): exag enters affinely
            f_attr = (np.asarray(g2.grad) - np.asarray(g1.grad)) / (4.0 * 3.0)
            assert np.isfinite(f_attr).all()
            assert np.abs(f_attr).max() > 0


# ------------------------------------------------------- sklearn parity -----
class TestEstimator:
    @pytest.mark.parametrize("method", ["exact", "barnes_hut", "fft"])
    def test_fit_transform_digits(self, method):
        x, _ = make_dataset("digits", n=300)
        est = TSNE(method=method, perplexity=12.0, n_iter=120, kl_every=60,
                   random_state=3)
        emb = est.fit_transform(x)
        assert emb.shape == (300, 2)
        assert np.isfinite(emb).all()
        assert np.isfinite(est.kl_divergence_)
        assert est.n_iter_ == 120
        assert est.embedding_ is emb
        assert est.n_features_in_ == x.shape[1]
        # learning_rate='auto' = max(N / early_exaggeration, 50)
        assert est.learning_rate_ == max(300 / 12.0, 50.0)

    def test_methods_agree_on_digits(self):
        x, _ = make_dataset("digits", n=300)
        kl = {}
        for method in ("exact", "barnes_hut", "fft"):
            est = TSNE(method=method, perplexity=12.0, n_iter=150, kl_every=150,
                       random_state=0, backend_options=dict(theta=0.2))
            est.fit(x)
            kl[method] = est.kl_divergence_
        assert abs(kl["barnes_hut"] - kl["exact"]) < 0.05
        # FFT's ~1% force error compounds over the descent trajectory into a
        # nearby local minimum; per-gradient agreement is asserted tightly in
        # TestBackendEquivalence
        assert abs(kl["fft"] - kl["exact"]) < 0.2

    def test_backend_instance_as_method(self):
        x, _ = make_points(128, seed=21, dim=8)
        est = TSNE(method=FFTBackend(n_boxes=32), perplexity=8.0, n_iter=60,
                   kl_every=30)
        emb = est.fit_transform(x)
        assert emb.shape == (128, 2) and np.isfinite(emb).all()
        # settings that a backend instance would silently ignore must raise
        with pytest.raises(ValueError, match="backend_options have no effect"):
            TSNE(method=FFTBackend(), perplexity=8.0,
                 backend_options={"fft_n_boxes": 96}).fit(x)
        with pytest.raises(ValueError, match="angle= has no effect"):
            TSNE(method=BarnesHutBackend(), perplexity=8.0, angle=0.8).fit(x)

    def test_callbacks_receive_iteration_stats(self):
        x, _ = make_points(200, seed=33, dim=8)
        seen = []
        est = TSNE(perplexity=10.0, n_iter=90, kl_every=30,
                   callbacks=[seen.append])
        est.fit(x)
        assert [s.iteration for s in seen] == [30, 60, 90]
        for s in seen:
            assert isinstance(s, IterationStats)
            assert np.isfinite(s.kl) and np.isfinite(s.grad_norm)
            assert s.z > 0 and s.max_traversal >= 0 and s.elapsed_s >= 0

    def test_min_grad_norm_early_stopping(self):
        x, _ = make_points(200, seed=33, dim=8)
        est = TSNE(perplexity=10.0, n_iter=400, kl_every=25, min_grad_norm=1e9)
        est.fit(x)
        assert est.n_iter_ == 25  # stops at the first convergence check

    def test_validation_errors(self):
        x, _ = make_points(64, seed=1)
        with pytest.raises(ValueError, match="2 dimensions"):
            TSNE(n_components=3, perplexity=5.0).fit(x)
        with pytest.raises(ValueError, match="perplexity"):
            TSNE(perplexity=50.0).fit(x)
        with pytest.raises(ValueError, match="2-D"):
            TSNE(perplexity=5.0).fit(x[:, 0])

    def test_get_set_params_roundtrip(self):
        est = TSNE(perplexity=17.0, method="fft")
        params = est.get_params()
        assert params["perplexity"] == 17.0 and params["method"] == "fft"
        est.set_params(perplexity=9.0)
        assert est.perplexity == 9.0
        with pytest.raises(ValueError, match="invalid parameter"):
            est.set_params(bogus=1)


# ------------------------------------------------------------- run_tsne -----
class TestRunTsne:
    def test_backend_override(self):
        x, _ = make_points(150, seed=41, dim=6)
        cfg = TsneConfig(perplexity=8.0, n_iter=60, exaggeration_iters=30,
                         momentum_switch_iter=30)
        res = run_tsne(x, cfg, backend=ExactBackend(), kl_every=30)
        assert np.isfinite(res.kl) and res.n_iter == 60
        assert res.y.shape == (150, 2)

    def test_method_from_config(self):
        x, _ = make_points(150, seed=43, dim=6)
        cfg = TsneConfig(perplexity=8.0, n_iter=60, exaggeration_iters=30,
                         momentum_switch_iter=30, method="fft")
        res = run_tsne(x, cfg, kl_every=30)
        assert np.isfinite(res.kl) and res.y.shape == (150, 2)
