"""repro.neighbors: registry round-trip, recall vs the exact oracle, and
KL-parity of BH t-SNE on an approximate vs exact neighbor graph."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tsne import TsneConfig, preprocess, run_tsne
from repro.data.datasets import make_dataset
from repro.neighbors import (
    ExactNeighbors, NNDescentNeighbors, RPForestNeighbors,
    available_neighbor_backends, make_neighbor_backend, recall_at_k,
    register_neighbor_backend, unregister_neighbor_backend,
)


@pytest.fixture(scope="module")
def digits_oracle():
    """digits-scale planted-cluster data + the exact KNN reference."""
    x, _ = make_dataset("digits")            # 1797 x 64, 10 clusters
    x = jnp.asarray(x)
    k = 15
    idx, d2 = ExactNeighbors().neighbors(x, k)
    return x, k, np.asarray(idx), np.asarray(d2)


# ------------------------------------------------------------- registry -----
class TestRegistry:
    def test_builtins_registered(self):
        assert {"exact", "rp_forest", "nn_descent"} <= set(
            available_neighbor_backends()
        )

    def test_unknown_method_raises(self):
        with pytest.raises(ValueError, match="unknown neighbor method"):
            make_neighbor_backend("nope")
        with pytest.raises(ValueError, match="unknown neighbor method"):
            preprocess(
                jnp.zeros((64, 4)), TsneConfig(perplexity=5.0,
                                               neighbor_method="nope"),
            )

    def test_options_flow_into_backend(self):
        be = make_neighbor_backend("rp_forest", {"n_trees": 3, "leaf_size": 32})
        assert be.n_trees == 3 and be.leaf_size == 32
        assert make_neighbor_backend("nn_descent", {"n_iters": 5}).n_iters == 5
        assert make_neighbor_backend("exact", {"block_q": 128}).block_q == 128

    def test_register_unregister_roundtrip(self):
        @register_neighbor_backend("tagged_exact")
        def make_tagged(**options):
            return ExactNeighbors(**options)

        try:
            assert "tagged_exact" in available_neighbor_backends()
            be = make_neighbor_backend("tagged_exact", {"block_db": 256})
            assert be.block_db == 256
            x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 4)),
                            jnp.float32)
            idx, d2 = be.neighbors(x, 5)
            assert idx.shape == (64, 5)
        finally:
            unregister_neighbor_backend("tagged_exact")
        assert "tagged_exact" not in available_neighbor_backends()
        # unregistering an unknown name is a no-op
        unregister_neighbor_backend("tagged_exact")

    def test_k_validation(self):
        x = jnp.zeros((8, 3))
        for name in ("exact", "rp_forest", "nn_descent"):
            with pytest.raises(ValueError, match="must be <"):
                make_neighbor_backend(name).neighbors(x, 8)


# ---------------------------------------------------------------- recall ----
class TestRecall:
    def _check_valid(self, idx, n, k):
        idx = np.asarray(idx)
        assert idx.shape == (n, k)
        assert ((idx >= 0) & (idx < n)).all(), "out-of-range neighbor index"
        assert not (idx == np.arange(n)[:, None]).any(), "self-neighbor"
        srt = np.sort(idx, axis=1)
        assert not (srt[:, 1:] == srt[:, :-1]).any(), "duplicate neighbor"

    def test_rp_forest_recall(self, digits_oracle):
        x, k, ref_idx, _ = digits_oracle
        idx, d2 = RPForestNeighbors().neighbors(x, k)
        self._check_valid(idx, x.shape[0], k)
        assert recall_at_k(ref_idx, idx) >= 0.90
        assert (np.asarray(d2) >= 0).all()

    def test_nn_descent_recall(self, digits_oracle):
        x, k, ref_idx, _ = digits_oracle
        idx, d2 = NNDescentNeighbors().neighbors(x, k)
        self._check_valid(idx, x.shape[0], k)
        assert recall_at_k(ref_idx, idx) >= 0.90
        assert (np.asarray(d2) >= 0).all()

    def test_refine_improves_forest(self, digits_oracle):
        x, k, ref_idx, _ = digits_oracle
        raw = RPForestNeighbors(n_trees=2, refine_iters=0).neighbors(x, k)[0]
        polished = RPForestNeighbors(n_trees=2, refine_iters=3).neighbors(x, k)[0]
        assert recall_at_k(ref_idx, polished) >= recall_at_k(ref_idx, raw)

    def test_approx_distances_are_exact_for_selected(self, digits_oracle):
        # approximate backends may pick suboptimal neighbors, but the d2 they
        # report for them must be the true squared distances
        x, k, _, _ = digits_oracle
        idx, d2 = RPForestNeighbors(n_trees=2).neighbors(x, k)
        xs = np.asarray(x)
        sub = slice(0, 200)
        ref = ((xs[sub, None, :] - xs[np.asarray(idx)[sub]]) ** 2).sum(-1)
        np.testing.assert_allclose(np.asarray(d2)[sub], ref, rtol=1e-3,
                                   atol=1e-2)


# ----------------------------------------------------------- n_neighbors ----
class TestNNeighborsParam:
    def test_default_and_override(self):
        cfg = TsneConfig(perplexity=10.0)
        assert cfg.resolve_n_neighbors(1000) == 30
        assert dataclasses.replace(cfg, n_neighbors=7).resolve_n_neighbors(1000) == 7

    def test_clamped_to_n_minus_one(self):
        # previously int(3 * perplexity) >= n tripped the k >= n ValueError
        cfg = TsneConfig(perplexity=10.0)
        assert cfg.resolve_n_neighbors(20) == 19
        x = jnp.asarray(np.random.default_rng(1).normal(size=(20, 5)),
                        jnp.float32)
        graph, timings = preprocess(x, cfg)
        assert timings["n_neighbors"] == 19

    def test_config_with_options_stays_hashable(self):
        # backends may embed the config as a static jit argument; a mapping
        # passed in is normalized to a sorted item tuple
        cfg = TsneConfig(neighbor_method="rp_forest",
                         neighbor_options={"n_trees": 4, "refine_iters": 1})
        hash(cfg)
        opts = cfg.resolve_neighbor_options()
        assert opts["n_trees"] == 4 and opts["refine_iters"] == 1

    def test_estimator_forwards(self):
        from repro.api import TSNE
        x, _ = make_dataset("digits", n=200)
        est = TSNE(perplexity=8.0, n_iter=40, kl_every=20, n_neighbors=10,
                   neighbor_method="rp_forest",
                   neighbor_options={"n_trees": 2, "refine_iters": 1})
        est.fit(x)
        assert est.timings_["n_neighbors"] == 10
        assert est.timings_["neighbor_method"] == "rp_forest"
        params = est.get_params()
        assert params["n_neighbors"] == 10
        assert params["neighbor_method"] == "rp_forest"


# ------------------------------------------------------------- KL parity ----
class TestKLParity:
    @pytest.mark.slow
    def test_bh_kl_on_approximate_graph(self):
        """BH t-SNE on an rp_forest graph lands within tolerance of the
        exact-graph KL (the paper's accuracy claim survives approximate KNN)."""
        x, _ = make_dataset("digits", n=800)
        kl = {}
        for method in ("exact", "rp_forest"):
            cfg = TsneConfig(perplexity=12.0, n_iter=150, exaggeration_iters=50,
                             momentum_switch_iter=50, seed=3,
                             neighbor_method=method)
            kl[method] = run_tsne(x, cfg, kl_every=150).kl
        assert np.isfinite(kl["rp_forest"])
        assert abs(kl["rp_forest"] - kl["exact"]) < 0.15


# ----------------------------------------------------- dataset stability ----
class TestDatasetSeed:
    def test_generation_deterministic(self):
        a, la = make_dataset("digits", n=64)
        b, lb = make_dataset("digits", n=64)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_name_digest_differentiates(self):
        a, _ = make_dataset("mnist", n=64)
        b, _ = make_dataset("fashion_mnist", n=64)   # same spec shape family
        assert a.shape == b.shape and not np.allclose(a, b)
