"""Paper Table 3: KL-divergence accuracy — BH approximation vs exact.

The paper's claim: acceleration does not compromise accuracy (Acc-t-SNE KL
within noise of scikit-learn/daal4py).  We verify the same property between
our exact O(N^2) gradient and the BH pipeline at theta in {0.2, 0.5, 0.8},
plus the float32-vs-float64-like comparison via Pallas/XLA path parity.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import exact, similarity
from repro.core.bsp import binary_search_perplexity
from repro.core.knn import knn
from repro.core.tsne import TsneConfig, run_tsne
from repro.data.datasets import make_dataset


def run(n: int = 1500, n_iter: int = 300, perplexity: float = 20.0):
    x, _ = make_dataset("digits", n=n)
    base = TsneConfig(perplexity=perplexity, n_iter=n_iter,
                      exaggeration_iters=100, momentum_switch_iter=100, seed=0)

    kls = {}
    for theta in (0.2, 0.5, 0.8):
        cfg = dataclasses.replace(base, theta=theta)
        res = run_tsne(x, cfg, kl_every=n_iter)
        # exact KL of the final embedding (not the BH estimate)
        k = cfg.n_neighbors()
        idx, d2 = knn(jnp.asarray(x), k)
        cond_p, _ = binary_search_perplexity(d2, perplexity)
        p_dense = similarity.dense_p_matrix(idx, cond_p)
        kl_exact = float(exact.exact_kl(jnp.asarray(res.y), jnp.asarray(p_dense, jnp.float32)))
        kls[theta] = (res.kl, kl_exact)
        emit(f"accuracy_theta{theta}_n{n}", 0.0,
             f"kl_bh_estimate={res.kl:.4f} kl_exact={kl_exact:.4f}")

    # the paper's acceptance criterion: KL within a few percent across methods
    vals = [v[1] for v in kls.values()]
    spread = (max(vals) - min(vals)) / max(min(vals), 1e-9)
    emit(f"accuracy_kl_spread_n{n}", 0.0, f"relative_spread={spread:.4f}")
