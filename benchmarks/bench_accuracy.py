"""Paper Table 3: KL-divergence accuracy — approximate backends vs exact.

The paper's claim: acceleration does not compromise accuracy (Acc-t-SNE KL
within noise of scikit-learn/daal4py).  We verify the same property through
the estimator API: the BH backend at theta in {0.2, 0.5, 0.8} and the FFT
backend, each scored by the exact KL of its final embedding.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import TSNE
from repro.core import exact, similarity
from repro.core.bsp import binary_search_perplexity
from repro.core.knn import knn
from repro.data.datasets import make_dataset


def run(n: int = 1500, n_iter: int = 300, perplexity: float = 20.0):
    x, _ = make_dataset("digits", n=n)
    base = dict(perplexity=perplexity, n_iter=n_iter, random_state=0,
                kl_every=n_iter,
                backend_options=dict(exaggeration_iters=100,
                                     momentum_switch_iter=100))

    # exact P for the final-embedding KL oracle (shared across variants)
    k = int(3 * perplexity)
    idx, d2 = knn(jnp.asarray(x), k)
    cond_p, _ = binary_search_perplexity(d2, perplexity)
    p_dense = jnp.asarray(similarity.dense_p_matrix(idx, cond_p), jnp.float32)

    def exact_kl_of(emb: np.ndarray) -> float:
        return float(exact.exact_kl(jnp.asarray(emb), p_dense))

    kls = {}
    for theta in (0.2, 0.5, 0.8):
        est = TSNE(method="barnes_hut", angle=theta, **base)
        emb = est.fit_transform(x)
        kl_exact = exact_kl_of(emb)
        kls[f"bh_theta{theta}"] = kl_exact
        emit(f"accuracy_theta{theta}_n{n}", 0.0,
             f"kl_bh_estimate={est.kl_divergence_:.4f} kl_exact={kl_exact:.4f}")

    est = TSNE(method="fft", **base)
    emb = est.fit_transform(x)
    kls["fft"] = exact_kl_of(emb)
    emit(f"accuracy_fft_n{n}", 0.0,
         f"kl_fft_estimate={est.kl_divergence_:.4f} kl_exact={kls['fft']:.4f}")

    # the paper's acceptance criterion: KL within a few percent across methods
    vals = list(kls.values())
    spread = (max(vals) - min(vals)) / max(min(vals), 1e-9)
    emit(f"accuracy_kl_spread_n{n}", 0.0, f"relative_spread={spread:.4f}")
