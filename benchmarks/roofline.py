"""Roofline report: reads the dry-run JSONs and prints the per-cell
three-term analysis (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m benchmarks.roofline [--dir runs/dryrun]
                                                 [--mesh pod256] [--markdown]

Terms (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):
    compute_s    = HLO_FLOPs(global)        / (chips * peak)
    memory_s     = HLO_bytes(global)        / (chips * hbm_bw)
    collective_s = collective_bytes(global) / (chips * link_bw)
cost_analysis() is per-device on the SPMD module, so global/chips == the
per-device quantity used directly against per-chip rates.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import ARCH_IDS, SHAPES


def load(dir_: pathlib.Path, mesh: str):
    cells = {}
    for f in sorted(dir_.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        cells[(r["arch"], r["shape"])] = r
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:8.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def report(dir_: str = "runs/dryrun", mesh: str = "pod256", markdown: bool = False):
    cells = load(pathlib.Path(dir_), mesh)
    sep = "|" if markdown else "  "
    hdr = ["arch", "shape", "compute", "memory", "collective", "bound",
           "model_TF", "hlo_TF", "useful", "MFU@bound"]
    if markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
              f"{'collective':>10s} {'bound':>10s} {'model_TF':>9s} {'hlo_TF':>9s} "
              f"{'useful':>7s} {'MFU@bound':>9s}")
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = cells.get((arch, shape))
            if r is None:
                continue
            if r.get("skipped"):
                row = [arch, shape, "-", "-", "-", "skipped", "-", "-", "-", "-"]
            elif not r.get("ok"):
                row = [arch, shape, "-", "-", "-", "FAILED", "-", "-", "-", "-"]
            else:
                roof = r["roofline"]
                mf = roof.get("model_flops") or 0.0
                hf = roof.get("hlo_flops_global") or 0.0
                useful = f"{mf/hf:5.2f}" if hf else "-"
                # achievable MFU if perfectly overlapped = compute / bound time
                mfu = roof["compute_s"] / roof["bound_time_s"] * (mf / hf if hf else 1.0)
                row = [arch, shape, fmt_s(roof["compute_s"]), fmt_s(roof["memory_s"]),
                       fmt_s(roof["collective_s"]), roof["dominant"],
                       f"{mf/1e12:9.1f}", f"{hf/1e12:9.1f}", useful, f"{mfu:8.1%}"]
            rows.append(row)
            if markdown:
                print("| " + " | ".join(str(c) for c in row) + " |")
            else:
                print(f"{row[0]:24s} {row[1]:12s} {row[2]:>10s} {row[3]:>10s} "
                      f"{row[4]:>10s} {row[5]:>10s} {row[6]:>9s} {row[7]:>9s} "
                      f"{row[8]:>7s} {row[9]:>9s}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="pod256", choices=["pod256", "pod512"])
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    report(args.dir, args.mesh, args.markdown)


if __name__ == "__main__":
    main()
