"""Roofline report, two modes.

Dry-run mode (default) reads the dry-run JSONs and prints the per-cell
three-term analysis (EXPERIMENTS.md §Roofline):

    PYTHONPATH=src python -m benchmarks.roofline [--dir runs/dryrun]
                                                 [--mesh pod256] [--markdown]

Terms (v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI):
    compute_s    = HLO_FLOPs(global)        / (chips * peak)
    memory_s     = HLO_bytes(global)        / (chips * hbm_bw)
    collective_s = collective_bytes(global) / (chips * link_bw)
cost_analysis() is per-device on the SPMD module, so global/chips == the
per-device quantity used directly against per-chip rates.

t-SNE mode (``--tsne``) is the kernel-target picker: it jits each t-SNE
hot path at a representative size, feeds the *post-optimization* HLO text
through ``launch/hlo_cost.analyze_hlo`` (loop-trip-count-aware flop/byte
counting), and prints the paths ranked by modeled memory traffic with
their arithmetic intensity and Pallas coverage from the ``kernels/ops``
registry.  Low intensity + high bytes + no kernel = the next target; this
is the analysis that picked ``bsp_search`` (64 whole-array passes, ~0.4
flops/byte) and ``fft_spread``/``fft_gather`` (serialized XLA scatter) —
see docs/KERNELS.md for how to read the output.

    PYTHONPATH=src python -m benchmarks.roofline --tsne [--n 20000] [--k 90]
                                                 [--boxes 48] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import pathlib


def load(dir_: pathlib.Path, mesh: str):
    cells = {}
    for f in sorted(dir_.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        cells[(r["arch"], r["shape"])] = r
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:8.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:7.2f}ms"
    return f"{x*1e6:7.1f}us"


def report(dir_: str = "runs/dryrun", mesh: str = "pod256", markdown: bool = False):
    from repro.configs import ARCH_IDS, SHAPES
    cells = load(pathlib.Path(dir_), mesh)
    sep = "|" if markdown else "  "
    hdr = ["arch", "shape", "compute", "memory", "collective", "bound",
           "model_TF", "hlo_TF", "useful", "MFU@bound"]
    if markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
              f"{'collective':>10s} {'bound':>10s} {'model_TF':>9s} {'hlo_TF':>9s} "
              f"{'useful':>7s} {'MFU@bound':>9s}")
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = cells.get((arch, shape))
            if r is None:
                continue
            if r.get("skipped"):
                row = [arch, shape, "-", "-", "-", "skipped", "-", "-", "-", "-"]
            elif not r.get("ok"):
                row = [arch, shape, "-", "-", "-", "FAILED", "-", "-", "-", "-"]
            else:
                roof = r["roofline"]
                mf = roof.get("model_flops") or 0.0
                hf = roof.get("hlo_flops_global") or 0.0
                useful = f"{mf/hf:5.2f}" if hf else "-"
                # achievable MFU if perfectly overlapped = compute / bound time
                mfu = roof["compute_s"] / roof["bound_time_s"] * (mf / hf if hf else 1.0)
                row = [arch, shape, fmt_s(roof["compute_s"]), fmt_s(roof["memory_s"]),
                       fmt_s(roof["collective_s"]), roof["dominant"],
                       f"{mf/1e12:9.1f}", f"{hf/1e12:9.1f}", useful, f"{mfu:8.1%}"]
            rows.append(row)
            if markdown:
                print("| " + " | ".join(str(c) for c in row) + " |")
            else:
                print(f"{row[0]:24s} {row[1]:12s} {row[2]:>10s} {row[3]:>10s} "
                      f"{row[4]:>10s} {row[5]:>10s} {row[6]:>9s} {row[7]:>9s} "
                      f"{row[8]:>7s} {row[9]:>9s}")
    return rows


# ---------------------------------------------------------------------------
# t-SNE hot-path ranking (--tsne)
# ---------------------------------------------------------------------------

# v5e single-chip rates — the machine balance that decides memory- vs
# compute-bound (~240 flops/byte crossover for f32-as-bf16 peak)
_PEAK_FLOPS = 197e12
_HBM_BW = 819e9


def _tsne_cases(n: int, k: int, n_boxes: int) -> dict:
    """name -> (fn, args): one jittable closure per t-SNE hot path.

    Names match the ``kernels/ops`` registry where a Pallas kernel exists,
    so the report can show coverage; ``bh_gradient_full`` and ``fft_conv``
    are the remaining XLA-only aggregates.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import _pairwise, attractive, bsp, morton
    from repro.core import fft_repulsion as fr

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, 50)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    d2 = jnp.asarray(np.abs(rng.normal(size=(n, k))).astype(np.float32))
    cols = jnp.asarray(rng.integers(0, n, size=(n, k)), jnp.int32)
    vals = jnp.asarray(rng.uniform(0, 1e-3, size=(n, k)).astype(np.float32))
    nodes = n_boxes * (fr.P_ORDER - 1) + 1
    base, wx, wy, _h = fr.interp_coords(y, n_boxes)
    charges = jnp.stack([jnp.ones((n,), jnp.float32), y[:, 0], y[:, 1]], axis=1)
    pot = jnp.zeros((nodes, nodes, 4), jnp.float32)
    cent, r_span = morton.span_radius(y)

    def bsp_case(d2):
        return bsp._binary_search_perplexity_xla(d2, 30.0)

    def spread_case(base, wx, wy, charges):
        return fr.spread_to_grid(base, wx, wy, charges, nodes)

    def fft_conv_case(y):
        # the FFT convolution half alone (stays XLA by design)
        return fr.fft_repulsion(y, n_boxes=n_boxes)

    def morton_case(y, cent, r_span):
        return morton.morton_encode(y, cent, r_span)

    return {
        "bsp_search": (bsp_case, (d2,)),
        "attractive_ell": (attractive.attractive_forces_ell, (y, cols, vals)),
        "pairwise_sq_dists": (_pairwise.pairwise_sq_dists, (x[:512], x)),
        "fft_spread": (spread_case, (base, wx, wy, charges)),
        "fft_gather": (fr.gather_from_grid, (pot, base, wx, wy)),
        "fft_conv": (fft_conv_case, (y,)),
        "morton_encode": (morton_case, (y, cent, r_span)),
    }


def tsne_report(n: int = 20000, k: int = 90, n_boxes: int = 48,
                markdown: bool = False):
    """Rank t-SNE hot paths by modeled HBM traffic of their compiled HLO."""
    import jax

    from repro.kernels.ops import available_kernels
    from repro.launch.hlo_cost import analyze_hlo

    kernelized = set(available_kernels())
    rows = []
    for name, (fn, args) in _tsne_cases(n, k, n_boxes).items():
        hlo = jax.jit(fn).lower(*args).compile().as_text()
        a = analyze_hlo(hlo)
        flops, byts = a["flops"], a["bytes"]
        intensity = flops / byts if byts else 0.0
        bound = "compute" if intensity > _PEAK_FLOPS / _HBM_BW else "memory"
        rows.append(dict(
            name=name, gflops=flops / 1e9, mbytes=byts / 1e6,
            intensity=intensity, bound=bound,
            pallas="yes" if name in kernelized else "no",
        ))
    rows.sort(key=lambda r: r["mbytes"], reverse=True)
    hdr = ["hot_path", "GFLOP", "MB_moved", "flops/byte", "bound", "pallas"]
    if markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for r in rows:
            print(f"| {r['name']} | {r['gflops']:.2f} | {r['mbytes']:.1f} "
                  f"| {r['intensity']:.2f} | {r['bound']} | {r['pallas']} |")
    else:
        print(f"{'hot_path':20s} {'GFLOP':>8s} {'MB_moved':>9s} "
              f"{'flops/byte':>11s} {'bound':>8s} {'pallas':>7s}")
        for r in rows:
            print(f"{r['name']:20s} {r['gflops']:8.2f} {r['mbytes']:9.1f} "
                  f"{r['intensity']:11.2f} {r['bound']:>8s} {r['pallas']:>7s}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="pod256", choices=["pod256", "pod512"])
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--tsne", action="store_true",
                    help="rank t-SNE hot paths by modeled HBM traffic")
    ap.add_argument("--n", type=int, default=20000, help="points (--tsne)")
    ap.add_argument("--k", type=int, default=90, help="neighbors (--tsne)")
    ap.add_argument("--boxes", type=int, default=48,
                    help="FFT grid boxes/dim (--tsne)")
    args = ap.parse_args()
    if args.tsne:
        tsne_report(args.n, args.k, args.boxes, args.markdown)
    else:
        report(args.dir, args.mesh, args.markdown)


if __name__ == "__main__":
    main()
