"""Neighbor-backend benchmark: recall@k + throughput vs the exact oracle.

    PYTHONPATH=src python benchmarks/bench_knn.py                # full sweep
    PYTHONPATH=src python benchmarks/bench_knn.py --smoke        # CI-sized

Every registered production backend runs against the exact blocked brute
force on mouse-like data (20-D, 30 planted clusters) across dataset scales
— by default up to 50k points, where the O(N²·D) exact scan is measurably
slower than the approximate backends and the gap keeps widening with N.
Emits ``name,us_per_call,derived`` rows; ``derived`` carries recall@k and
the speedup over exact.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):                # `python benchmarks/bench_knn.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.common import emit
from repro.data.datasets import make_dataset
from repro.neighbors import (
    available_neighbor_backends, make_neighbor_backend, recall_at_k,
)


def _timed(backend, x, k, iters: int) -> tuple[float, np.ndarray]:
    """Median warm wall-seconds and the neighbor indices."""
    idx, d2 = backend.neighbors(x, k)          # warmup (compile)
    jax.block_until_ready(idx)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        idx, d2 = backend.neighbors(x, k)
        jax.block_until_ready(idx)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), np.asarray(idx)


def run(sizes=(2000, 10000, 50000), k: int = 30, variants=None):
    if variants is None:   # every registered backend, at default settings
        variants = {name: {} for name in available_neighbor_backends()
                    if name != "exact"}
    for n in sizes:
        x, _ = make_dataset("mouse_1p3m", n=n)
        x = jnp.asarray(x)
        iters = 1 if n >= 20000 else 3
        t_exact, ref_idx = _timed(make_neighbor_backend("exact"), x, k, iters)
        emit(f"knn_n{n}_exact", t_exact * 1e6, "recall=1.000")
        for name, opts in variants.items():
            t, idx = _timed(make_neighbor_backend(name, opts), x, k, iters)
            rec = recall_at_k(ref_idx, idx)
            emit(f"knn_n{n}_{name}", t * 1e6,
                 f"recall={rec:.3f} speedup_vs_exact={t_exact / t:.2f}x")
            assert rec > 0.3, f"{name} recall collapsed ({rec:.3f}) at n={n}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run (small n, shrunk backends)")
    ap.add_argument("--sizes", default="2000,10000,50000")
    ap.add_argument("--k", type=int, default=30)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    if args.smoke:
        run(sizes=(1500,), k=15, variants={
            "rp_forest": {"n_trees": 4, "refine_iters": 1},
            "nn_descent": {"n_iters": 4},
        })
    else:
        run(sizes=tuple(int(s) for s in args.sizes.split(",")), k=args.k)
    print(f"# total_bench_wall_s,{time.time() - t0:.1f},", file=sys.stderr)


if __name__ == "__main__":
    main()
