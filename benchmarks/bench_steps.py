"""Paper Tables 5/6: per-step time, daal4py-like naive vs Acc-t-SNE-like
optimized, both executed for real on CPU.

The "naive" column reimplements the baseline's work profile (per-level point
re-partitioning, level-barrier summarization, sequential per-row loops,
uncompressed tree); "optimized" is this framework's Morton pipeline.
Absolute times are this container's single CPU core; the *ratio* is the
algorithmic reproduction of the paper's speedups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import build_tree, emit, time_fn, tsne_fixture
from repro.core import bsp, morton, naive, quadtree
from repro.core.attractive import attractive_forces_ell
from repro.core.repulsive import bh_repulsion_sorted
from repro.core.summarize import summarize
from repro.kernels.ops import attractive_forces_ell as attractive_pallas
from repro.kernels.ops import morton_encode as morton_pallas


@functools.partial(jax.jit, static_argnames=("iters",))
def _bsp_rowloop(d2, perplexity, iters=64):
    """single-thread-like BSP: sequential over rows (lax.map)."""
    def one(row):
        p, b = bsp.binary_search_perplexity(row[None, :], perplexity, iters=iters)
        return p[0]
    return jax.lax.map(one, d2)


@jax.jit
def _morton_pipeline(y):
    cent, r = morton.span_radius(y)
    codes = morton.morton_encode(y, cent, r)
    cs, ys, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(cs)
    return tree.n_nodes, cs, ys


@jax.jit
def _summarize_only(tree, ys, r):
    return summarize(tree, ys, r)


def run(n: int = 20000, perplexity: float = 30.0, theta: float = 0.5):
    fx = tsne_fixture(n, perplexity=perplexity)
    y = fx["y"]

    # --- BSP (paper: 1.0x single-thread, 17x multithreaded) ---
    # the sequential row loop is timed on a row subsample (it is O(rows) by
    # construction); both variants see the same rows so the ratio is fair
    n_bsp = min(n, 2000)
    d2_sub = fx["d2"][:n_bsp]
    t_naive = time_fn(lambda: _bsp_rowloop(d2_sub, perplexity), iters=2)
    t_opt = time_fn(lambda: bsp.binary_search_perplexity(d2_sub, perplexity))
    t_bsp_pl = time_fn(
        lambda: bsp.binary_search_perplexity(d2_sub, perplexity, impl="pallas"),
        iters=2,
    )
    emit(f"bsp_naive_rowloop_n{n_bsp}", t_naive, "")
    emit(f"bsp_vectorized_n{n_bsp}", t_opt, f"speedup={t_naive / t_opt:.1f}x")
    emit(f"bsp_pallas_n{n_bsp}", t_bsp_pl, "(interpret mode)")

    # --- Quadtree building (paper: 4.5x single-thread, 14.3x multicore) ---
    t_naive = time_fn(lambda: naive.naive_build_and_summarize(y)[0])
    t_opt = time_fn(lambda: _morton_pipeline(y)[0])
    emit(f"tree_naive_levelwise_n{n}", t_naive, "")
    emit(f"tree_morton_n{n}", t_opt, f"speedup={t_naive / t_opt:.1f}x")

    # --- Summarization (paper: 5.3x / 32.4x) ---
    cent, r, codes, cs, ys, perm, tree = build_tree(y)
    # naive: the level-synchronized segment reduction inside the naive build
    t_naive_sum = time_fn(lambda: naive.naive_build_and_summarize(y)[1][-1])
    t_opt_sum = time_fn(lambda: _summarize_only(tree, ys, r).com)
    emit(f"summarize_levelwise_n{n}", t_naive_sum, "(includes naive build)")
    emit(f"summarize_prefix_n{n}", t_opt_sum, f"speedup={t_naive_sum / t_opt_sum:.1f}x")

    # --- Attractive (paper: 2.2x single-thread) ---
    # every variant timed under jit — the production path (tsne_step) always
    # runs jitted; eager lax.map dispatch would mis-time the blocked kernel
    from repro.core.attractive import attractive_forces_ell_blocked
    ell_j = jax.jit(attractive_forces_ell)
    blk_j = jax.jit(attractive_forces_ell_blocked, static_argnames=("block",))
    t_naive = time_fn(lambda: naive.naive_attractive(y, fx["cols"], fx["vals"]))
    t_opt = time_fn(lambda: ell_j(y, fx["cols"], fx["vals"])[0])
    t_blk = time_fn(lambda: blk_j(y, fx["cols"], fx["vals"])[0])
    t_pallas = time_fn(lambda: attractive_pallas(y, fx["cols"], fx["vals"])[0])
    emit(f"attractive_rowloop_n{n}", t_naive, "")
    emit(f"attractive_vectorized_n{n}", t_opt, f"speedup={t_naive / t_opt:.1f}x")
    emit(f"attractive_blocked_n{n}", t_blk,
         f"speedup={t_naive / t_blk:.1f}x (cache-blocked, default)")
    emit(f"attractive_pallas_n{n}", t_pallas,
         f"speedup={t_naive / t_pallas:.1f}x (interpret mode)")

    # --- Repulsive (paper: 6.0x single-thread) ---
    summ = _summarize_only(tree, ys, r)
    _, _, _, csu, ysu, permu, tree_u = build_tree(y, compress=False)
    summ_u = _summarize_only(tree_u, ysu, r)
    t_naive = time_fn(lambda: bh_repulsion_sorted(ysu, tree_u, summ_u, theta).force, iters=3)
    t_opt = time_fn(lambda: bh_repulsion_sorted(ys, tree, summ, theta).force, iters=3)
    steps_u = int(jnp.max(bh_repulsion_sorted(ysu, tree_u, summ_u, theta).steps))
    steps_c = int(jnp.max(bh_repulsion_sorted(ys, tree, summ, theta).steps))
    emit(f"repulsive_uncompressed_n{n}", t_naive, f"max_traversal={steps_u}")
    emit(f"repulsive_compressed_n{n}", t_opt,
         f"speedup={t_naive / t_opt:.1f}x max_traversal={steps_c}")

    # --- Morton code formation (Alg. 1) xla vs pallas ---
    cent, r = morton.span_radius(y)
    t_xla = time_fn(lambda: morton.morton_encode(y, cent, r))
    t_pl = time_fn(lambda: morton_pallas(y, cent, r))
    emit(f"morton_xla_n{n}", t_xla, "")
    emit(f"morton_pallas_n{n}", t_pl, "(interpret mode)")

    # --- FFT-repulsion interpolation spread/gather, xla vs pallas ---
    from repro.core.fft_repulsion import fft_repulsion
    fft_n = min(n, 4000)
    y_fft = y[:fft_n]
    t_fx = time_fn(lambda: fft_repulsion(y_fft, n_boxes=48)[0], iters=3)
    t_fp = time_fn(
        lambda: fft_repulsion(y_fft, n_boxes=48, interp_impl="pallas")[0],
        iters=2,
    )
    emit(f"fft_interp_xla_n{fft_n}", t_fx, "")
    emit(f"fft_interp_pallas_n{fft_n}", t_fp, "(interpret mode)")
