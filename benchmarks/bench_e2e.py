"""Paper Fig. 4 + Table 4: end-to-end t-SNE across the six datasets.

Runs everything through the public ``repro.api.TSNE`` estimator: the
naive-baseline configuration (uncompressed daal4py-like tree) against the
optimized Morton pipeline, the Pallas-kernel route, and the FIt-SNE-style
FFT backend.  Dataset sizes are scaled by ``--scale`` so the full suite
fits a single-core CPU budget; pass --scale 1.0 for paper-size runs.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, record_phases
from repro.api import TSNE
from repro.data.datasets import SPECS, make_dataset

BENCH_SETS = ["digits", "mnist", "fashion_mnist", "cifar10", "svhn", "mouse_1p3m"]
DEFAULT_CAP = {"digits": 1797, "mnist": 8000, "fashion_mnist": 8000,
               "cifar10": 4000, "svhn": 4000, "mouse_1p3m": 20000}


def run(n_iter: int = 250, scale: float = 1.0, perplexity: float = 30.0):
    for name in BENCH_SETS:
        n = min(SPECS[name].n, int(DEFAULT_CAP[name] * scale))
        x, _ = make_dataset(name, n=n)
        if x.shape[1] > 50:      # paper applies t-SNE post-PCA for mouse only;
            x = x[:, :50]        # we cap input dim so KNN cost stays CPU-sane
        swap = min(250, n_iter // 2)

        def make(method="barnes_hut", **backend_opts):
            return TSNE(method=method, perplexity=perplexity, n_iter=n_iter,
                        random_state=0, kl_every=n_iter,
                        backend_options=dict(exaggeration_iters=swap,
                                             momentum_switch_iter=swap,
                                             **backend_opts))

        variants = {
            "naive_bh": make(compress_tree=False),
            "acc_tsne": make(),
            "acc_tsne_pallas": make(use_pallas=True),
            "fft": make(method="fft"),
        }
        times, kls = {}, {}
        for vname, est in variants.items():
            t0 = time.perf_counter()
            est.fit(x)
            times[vname] = time.perf_counter() - t0
            kls[vname] = est.kl_divergence_
            # per-phase breakdown (paper Tables 5/6) into the JSON artifact
            record_phases(f"e2e_{name}_n{n}_{vname}", est.timings_)
        sp = times["naive_bh"] / times["acc_tsne"]
        for vname in variants:
            emit(f"e2e_{name}_n{n}_{vname}", times[vname] * 1e6,
                 f"kl={kls[vname]:.3f}" + (f" speedup_vs_naive={sp:.2f}x"
                                           if vname == "acc_tsne" else ""))
