"""Paper Fig. 4 + Table 4: end-to-end t-SNE across the six datasets.

Compares the naive-baseline configuration (uncompressed daal4py-like tree +
row-loop-free but unfused path) against the optimized Morton pipeline, and
the exact O(N^2) method where feasible.  Dataset sizes are scaled by
``--scale`` so the full suite fits a single-core CPU budget; pass
--scale 1.0 for paper-size runs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro.core.tsne import TsneConfig, run_tsne
from repro.data.datasets import SPECS, make_dataset

BENCH_SETS = ["digits", "mnist", "fashion_mnist", "cifar10", "svhn", "mouse_1p3m"]
DEFAULT_CAP = {"digits": 1797, "mnist": 8000, "fashion_mnist": 8000,
               "cifar10": 4000, "svhn": 4000, "mouse_1p3m": 20000}


def run(n_iter: int = 250, scale: float = 1.0, perplexity: float = 30.0):
    for name in BENCH_SETS:
        n = min(SPECS[name].n, int(DEFAULT_CAP[name] * scale))
        x, _ = make_dataset(name, n=n)
        if x.shape[1] > 50:      # paper applies t-SNE post-PCA for mouse only;
            x = x[:, :50]        # we cap input dim so KNN cost stays CPU-sane
        base = TsneConfig(perplexity=perplexity, n_iter=n_iter,
                          exaggeration_iters=min(250, n_iter // 2),
                          momentum_switch_iter=min(250, n_iter // 2), seed=0)
        variants = {
            "naive_bh": dataclasses.replace(base, compress_tree=False),
            "acc_tsne": base,
            "acc_tsne_pallas": dataclasses.replace(base, use_pallas=True),
        }
        times, kls = {}, {}
        for vname, cfg in variants.items():
            t0 = time.perf_counter()
            res = run_tsne(x, cfg, kl_every=n_iter)
            times[vname] = time.perf_counter() - t0
            kls[vname] = res.kl
        sp = times["naive_bh"] / times["acc_tsne"]
        for vname in variants:
            emit(f"e2e_{name}_n{n}_{vname}", times[vname] * 1e6,
                 f"kl={kls[vname]:.3f}" + (f" speedup_vs_naive={sp:.2f}x"
                                           if vname == "acc_tsne" else ""))
