"""Shared benchmark utilities: timing, CSV emission, JSON artifacts,
fixture construction."""
from __future__ import annotations

import datetime
import json
import os
import pathlib
import platform
import re
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bsp, morton, quadtree
from repro.core.knn import knn
from repro.core.similarity import symmetrize_ell
from repro.data.datasets import make_dataset

ROWS: list[tuple[str, float, str]] = []

# per-run-name phase breakdown (knn / bsp / symmetrize / gradient_descent
# seconds, the paper-Tables-5/6 view) — populated by benches that drive the
# full pipeline, persisted under "phases" in the BENCH_<n>.json artifact
PHASES: dict[str, dict] = {}


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def record_phases(name: str, timings: dict | None) -> None:
    """Store a fit's per-phase timing dict (``TSNE().timings_`` /
    ``run_tsne`` timings) under ``name`` for the JSON artifact."""
    if not timings:
        return
    PHASES[name] = {
        k: (round(v, 6) if isinstance(v, float) else v)
        for k, v in timings.items()
    }


def git_provenance() -> dict:
    """Commit hash + dirty flag of the repo this run came from, so BENCH
    artifacts are attributable to a source state ('numbers in commit
    messages' was the failure mode).  Empty dict outside a git checkout."""
    root = pathlib.Path(__file__).resolve().parent.parent
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True,
        ).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=root, capture_output=True,
            text=True, timeout=10, check=True,
        ).stdout.strip())
        return dict(commit=commit, dirty=dirty)
    except Exception:
        return {}


def machine_info() -> dict:
    """Host fingerprint stored alongside every benchmark artifact, so
    numbers from different machines are never compared blind."""
    return dict(
        platform=platform.platform(),
        processor=platform.processor() or platform.machine(),
        python=platform.python_version(),
        cpu_count=os.cpu_count(),
        jax=jax.__version__,
        jax_backend=jax.default_backend(),
        devices=[str(d) for d in jax.devices()],
    )


def next_bench_path(out_dir) -> pathlib.Path:
    """First free ``BENCH_<n>.json`` slot in ``out_dir`` (monotonic n)."""
    out_dir = pathlib.Path(out_dir)
    taken = [
        int(m.group(1))
        for f in out_dir.glob("BENCH_*.json")
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", f.name))
    ]
    return out_dir / f"BENCH_{max(taken, default=-1) + 1}.json"


def write_bench_json(out_dir, *, benches, argv, wall_s) -> pathlib.Path:
    """Persist every row emitted so far as the next ``BENCH_<n>.json``.

    The artifact is the per-PR perf trajectory: ``results`` mirrors the CSV
    rows (name / us_per_call / derived), plus machine info, git provenance
    (commit + dirty flag), and ``phases`` — the per-fit
    knn/bsp/symmetrize/gradient_descent breakdown recorded through
    :func:`record_phases`, the artifact form of the paper's Tables 5/6 —
    so regressions are diffable across commits instead of living only in
    commit messages.
    """
    pathlib.Path(out_dir).mkdir(parents=True, exist_ok=True)
    path = next_bench_path(out_dir)
    payload = dict(
        schema=2,
        created=datetime.datetime.now(datetime.timezone.utc).isoformat(),
        argv=list(argv),
        benches=list(benches),
        machine=machine_info(),
        git=git_provenance(),
        total_wall_s=round(wall_s, 2),
        results=[
            dict(name=n, us_per_call=round(us, 1), derived=d)
            for n, us, d in ROWS
        ],
        phases=dict(PHASES),
    )
    path.write_text(json.dumps(payload, indent=2) + "\n")
    return path


def load_bench_json(path) -> dict:
    """Read a ``BENCH_<n>.json`` artifact, accepting every schema so far.

    schema 1 (PR 6) lacks ``git`` and ``phases``; schema 2 (PR 7) added
    them.  Both carry the ``results`` rows ``--compare`` needs, so either
    side of a comparison may be either version (docs/BENCHMARKS.md).
    """
    doc = json.loads(pathlib.Path(path).read_text())
    schema = doc.get("schema")
    if schema not in (1, 2):
        raise ValueError(
            f"{path}: unsupported BENCH schema {schema!r} (known: 1, 2)"
        )
    if not isinstance(doc.get("results"), list):
        raise ValueError(f"{path}: no results rows")
    return doc


def compare_runs(prev_doc: dict, rows, threshold: float = 0.25):
    """Per-bench deltas of ``rows`` (current (name, us, derived) tuples)
    against a previous artifact's ``results``.

    Returns ``(lines, regressions)``: formatted report lines, and a list of
    ``(name, prev_us, cur_us, delta)`` for every matched bench more than
    ``threshold`` slower than before.  Benches present on only one side are
    reported (``NEW`` / ``not run``) but never gate — the trajectory must
    tolerate benches being added, renamed, or skipped between PRs.
    """
    prev = {r["name"]: float(r["us_per_call"])
            for r in prev_doc.get("results", [])}
    header = (f"{'bench':44s} {'current_us':>12s} {'previous_us':>12s} "
              f"{'delta':>8s}")
    lines = [header]
    regressions = []
    cur_names = set()
    for name, us, _derived in rows:
        cur_names.add(name)
        if name not in prev:
            lines.append(f"{name:44s} {us:12.1f} {'-':>12s} {'NEW':>8s}")
            continue
        p = prev[name]
        delta = (us - p) / p if p > 0 else 0.0
        flag = f"  REGRESSION (>{threshold:.0%})" if delta > threshold else ""
        lines.append(
            f"{name:44s} {us:12.1f} {p:12.1f} {delta:+8.1%}{flag}"
        )
        if delta > threshold:
            regressions.append((name, p, us, delta))
    for name, p in prev.items():
        if name not in cur_names:
            lines.append(f"{name:44s} {'-':>12s} {p:12.1f} {'not run':>8s}")
    return lines, regressions


def time_fn(fn, *args, warmup: int = 1, iters: int = 5, **kw) -> float:
    """Median wall time (us) of a blocking call."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def tsne_fixture(n: int, dim: int = 20, perplexity: float = 30.0, seed: int = 0):
    """KNN+BSP+sym P and a mid-optimization embedding for step benchmarks."""
    x, labels = make_dataset("mouse_1p3m", n=n, seed=seed)
    x = x[:, :dim]
    k = int(3 * perplexity)
    idx, d2 = knn(jnp.asarray(x), k)
    cond_p, _ = bsp.binary_search_perplexity(d2, perplexity)
    cols, vals = symmetrize_ell(idx, cond_p)
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    return dict(x=x, labels=labels, idx=idx, d2=d2, cond_p=cond_p,
                cols=jnp.asarray(cols), vals=jnp.asarray(vals, jnp.float32), y=y)


def build_tree(y, depth=16, compress=True):
    cent, r = morton.span_radius(y)
    codes = morton.morton_encode(y, cent, r, depth=depth)
    cs, ys, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(cs, depth=depth, compress=compress)
    return cent, r, codes, cs, ys, perm, tree
