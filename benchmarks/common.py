"""Shared benchmark utilities: timing, CSV emission, fixture construction."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bsp, morton, quadtree
from repro.core.knn import knn
from repro.core.similarity import symmetrize_ell
from repro.data.datasets import make_dataset

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, warmup: int = 1, iters: int = 5, **kw) -> float:
    """Median wall time (us) of a blocking call."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def tsne_fixture(n: int, dim: int = 20, perplexity: float = 30.0, seed: int = 0):
    """KNN+BSP+sym P and a mid-optimization embedding for step benchmarks."""
    x, labels = make_dataset("mouse_1p3m", n=n, seed=seed)
    x = x[:, :dim]
    k = int(3 * perplexity)
    idx, d2 = knn(jnp.asarray(x), k)
    cond_p, _ = bsp.binary_search_perplexity(d2, perplexity)
    cols, vals = symmetrize_ell(idx, cond_p)
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
    return dict(x=x, labels=labels, idx=idx, d2=d2, cond_p=cond_p,
                cols=jnp.asarray(cols), vals=jnp.asarray(vals, jnp.float32), y=y)


def build_tree(y, depth=16, compress=True):
    cent, r = morton.span_radius(y)
    codes = morton.morton_encode(y, cent, r, depth=depth)
    cs, ys, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(cs, depth=depth, compress=compress)
    return cent, r, codes, cs, ys, perm, tree
