"""Paper Fig. 5/6 analogue: scaling behaviour.

Core-count scaling is not measurable on this 1-core container, so we verify
the two *algorithmic* scaling claims that core scaling rests on:

  1. O(N log N) gradient step: fit the growth exponent of step time vs N —
     BH must stay near ~1 (vs 2 for the exact method);
  2. traversal work per point grows ~log N (the quadtree is doing its job);
  3. device-count scaling of the distributed step is exercised functionally
     in tests/test_distributed.py (emulated devices share this one core, so
     wall-clock parallel efficiency is not meaningful here).

:func:`run_large` (``benchmarks.run --bench scaling --large``, slow-gated —
never part of the quick CI pass) extends the trajectory past the historical
32k ceiling: it drives the *fused* million-point pipeline — sharded
approximate KNN + chunked BSP/symmetrization + gradient steps — at
100k/500k/1M points, emitting per-phase rows and peak-RSS through
``benchmarks.common`` so the large-N exponent lands in the ``BENCH_<n>.json``
artifact trajectory instead of only stdout.
"""
from __future__ import annotations

import functools
import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_tree, emit, record_phases, time_fn
from repro.core import exact
from repro.core.repulsive import bh_repulsion_sorted
from repro.core.summarize import summarize


@jax.jit
def _bh_step(y):
    cent, r, codes, cs, ys, perm, tree = _build(y)
    summ = summarize(tree, ys, r)
    rep = bh_repulsion_sorted(ys, tree, summ, 0.5)
    return rep.force, rep.steps


def _build(y):
    from repro.core import morton, quadtree
    cent, r = morton.span_radius(y)
    codes = morton.morton_encode(y, cent, r)
    cs, ys, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(cs)
    return cent, r, codes, cs, ys, perm, tree


def run(sizes=(2000, 4000, 8000, 16000, 32000), exact_cap: int = 8000):
    rng = np.random.default_rng(0)
    bh_times, ex_times, trav = [], [], []
    for n in sizes:
        y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        t = time_fn(lambda yy=y: _bh_step(yy)[0], iters=3)
        steps = np.asarray(_bh_step(y)[1])
        bh_times.append(t)
        trav.append(steps.mean())
        emit(f"scaling_bh_step_n{n}", t, f"mean_traversal={steps.mean():.0f}")
        if n <= exact_cap:
            te = time_fn(lambda yy=y: exact.exact_repulsion(yy)[0], iters=2)
            ex_times.append((n, te))
            emit(f"scaling_exact_step_n{n}", te, "")

    ln = np.log(np.asarray(sizes, np.float64))
    bh_slope = np.polyfit(ln, np.log(bh_times), 1)[0]
    emit("scaling_bh_exponent", 0.0, f"t ~ N^{bh_slope:.2f} (target ~1, exact=2)")
    if len(ex_times) >= 2:
        en = np.log([e[0] for e in ex_times])
        ev = np.log([e[1] for e in ex_times])
        ex_slope = np.polyfit(en, ev, 1)[0]
        emit("scaling_exact_exponent", 0.0, f"t ~ N^{ex_slope:.2f}")
    # traversal growth ~ log N: ratio of means across a 16x N range
    emit("scaling_traversal_growth", 0.0,
         f"mean_traversal {trav[0]:.0f} -> {trav[-1]:.0f} over {sizes[0]}->{sizes[-1]} pts")


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_large(
    sizes=(100_000, 500_000, 1_000_000),
    *,
    n_steps: int = 5,
    chunk_size: int = 100_000,
    method: str = "fft",
    perplexity: float = 30.0,
):
    """Fused large-N pipeline: sharded KNN + chunked preprocess + GD steps.

    One preprocessing pass and ``n_steps`` timed gradient iterations per
    size, on the memory-bounded path (``neighbor_method='sharded'`` +
    ``chunk_size``).  Emits per-phase rows, peak-RSS, and the fitted
    step-time exponent over the large range — directly comparable against
    ``scaling_bh_exponent`` from the <=32k ladder.
    """
    from repro.api import make_backend
    from repro.core.tsne import TsneConfig, init_state, preprocess, tsne_step
    from repro.data.datasets import make_dataset

    step_times = []
    for n in sizes:
        cfg = TsneConfig(
            perplexity=perplexity, neighbor_method="sharded",
            chunk_size=chunk_size, method=method,
        )
        x, _ = make_dataset("mouse_1p3m", n=n)
        t0 = time.perf_counter()
        graph, timings = preprocess(jnp.asarray(x), cfg)
        pre_s = time.perf_counter() - t0
        emit(f"scaling_large_preprocess_n{n}", pre_s * 1e6,
             f"knn={timings['knn']:.1f}s bsp={timings['bsp']:.1f}s "
             f"sym={timings['symmetrize']:.1f}s peak_rss_mb={_peak_rss_mb():.0f}")

        backend = make_backend(cfg.method, cfg, n)
        state = init_state(n, cfg)
        lr = cfg.resolve_lr(n)
        exag = jnp.asarray(cfg.early_exaggeration, jnp.float32)
        mom = jnp.asarray(cfg.momentum_initial, jnp.float32)

        def one_step(s):
            new_s, stats = tsne_step(s, graph, exag, mom, backend=backend,
                                     lr=lr, min_gain=cfg.min_gain)
            return new_s

        state = one_step(state)                    # compile + warm
        jax.block_until_ready(state.y)
        t1 = time.perf_counter()
        for _ in range(n_steps):
            state = one_step(state)
        jax.block_until_ready(state.y)
        step_s = (time.perf_counter() - t1) / n_steps
        step_times.append(step_s)
        emit(f"scaling_large_step_n{n}", step_s * 1e6,
             f"method={method} peak_rss_mb={_peak_rss_mb():.0f}")
        timings["gradient_descent"] = step_s * n_steps
        record_phases(f"scaling_large_n{n}", timings)

    if len(sizes) >= 2:
        ln = np.log(np.asarray(sizes, np.float64))
        slope = np.polyfit(ln, np.log(step_times), 1)[0]
        emit("scaling_large_step_exponent", 0.0,
             f"t ~ N^{slope:.2f} over {sizes[0]}..{sizes[-1]} (target ~1)")
