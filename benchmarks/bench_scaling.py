"""Paper Fig. 5/6 analogue: scaling behaviour.

Core-count scaling is not measurable on this 1-core container, so we verify
the two *algorithmic* scaling claims that core scaling rests on:

  1. O(N log N) gradient step: fit the growth exponent of step time vs N —
     BH must stay near ~1 (vs 2 for the exact method);
  2. traversal work per point grows ~log N (the quadtree is doing its job);
  3. device-count scaling of the distributed step is exercised functionally
     in tests/test_distributed.py (emulated devices share this one core, so
     wall-clock parallel efficiency is not meaningful here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_tree, emit, time_fn
from repro.core import exact
from repro.core.repulsive import bh_repulsion_sorted
from repro.core.summarize import summarize


@jax.jit
def _bh_step(y):
    cent, r, codes, cs, ys, perm, tree = _build(y)
    summ = summarize(tree, ys, r)
    rep = bh_repulsion_sorted(ys, tree, summ, 0.5)
    return rep.force, rep.steps


def _build(y):
    from repro.core import morton, quadtree
    cent, r = morton.span_radius(y)
    codes = morton.morton_encode(y, cent, r)
    cs, ys, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(cs)
    return cent, r, codes, cs, ys, perm, tree


def run(sizes=(2000, 4000, 8000, 16000, 32000), exact_cap: int = 8000):
    rng = np.random.default_rng(0)
    bh_times, ex_times, trav = [], [], []
    for n in sizes:
        y = jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32))
        t = time_fn(lambda yy=y: _bh_step(yy)[0], iters=3)
        steps = np.asarray(_bh_step(y)[1])
        bh_times.append(t)
        trav.append(steps.mean())
        emit(f"scaling_bh_step_n{n}", t, f"mean_traversal={steps.mean():.0f}")
        if n <= exact_cap:
            te = time_fn(lambda yy=y: exact.exact_repulsion(yy)[0], iters=2)
            ex_times.append((n, te))
            emit(f"scaling_exact_step_n{n}", te, "")

    ln = np.log(np.asarray(sizes, np.float64))
    bh_slope = np.polyfit(ln, np.log(bh_times), 1)[0]
    emit("scaling_bh_exponent", 0.0, f"t ~ N^{bh_slope:.2f} (target ~1, exact=2)")
    if len(ex_times) >= 2:
        en = np.log([e[0] for e in ex_times])
        ev = np.log([e[1] for e in ex_times])
        ex_slope = np.polyfit(en, ev, 1)[0]
        emit("scaling_exact_exponent", 0.0, f"t ~ N^{ex_slope:.2f}")
    # traversal growth ~ log N: ratio of means across a 16x N range
    emit("scaling_traversal_growth", 0.0,
         f"mean_traversal {trav[0]:.0f} -> {trav[-1]:.0f} over {sizes[0]}->{sizes[-1]} pts")
