"""Benchmark harness — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--bench steps,e2e,accuracy,scaling,knn]
                                            [--quick] [--large] [--n N] [--scale S]
                                            [--out-dir DIR | --no-json]
                                            [--trace [PATH]]
                                            [--compare PREV.json]
                                            [--compare-threshold 0.25]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit) and
persists the full run — rows + per-phase fit breakdowns (paper Tables 5/6)
+ machine info + git provenance — as the next ``BENCH_<n>.json`` in
``--out-dir`` (default: the repo root), the per-PR perf-trajectory artifact
the ROADMAP calls for.  ``--bench`` names are validated against the known
set; an unknown name (e.g. a typo like ``--bench step``) is an error, not a
silent no-op run.  ``--trace`` enables the process-global span tracer for
the whole run and writes a Perfetto-loadable Chrome-trace JSON (default
``trace_bench.json`` next to the artifact).
``--compare PREV.json`` turns the artifact chain into a *regression gate*:
after the run, every bench present in both runs is diffed
(``benchmarks.common.compare_runs``) and the process exits nonzero if any
matched bench is more than ``--compare-threshold`` (default 25%) slower —
wired into CI against the previous run's uploaded artifact, so the
trajectory accumulates AND regressions fail the build instead of living
silently in commit messages.  The new artifact is still written first:
a regressing run is recorded, then failed.
Paper mapping: steps -> Tables 5/6; e2e -> Table 4 / Fig 4; accuracy ->
Table 3; scaling -> Fig 5/6 (algorithmic form — see bench_scaling docstring).
Roofline reporting lives in benchmarks/roofline.py (dry-run JSON mode plus
``--tsne``, the compiled-HLO hot-path ranking that picks kernel targets).
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

KNOWN_BENCHES = ("steps", "accuracy", "scaling", "e2e", "knn")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=",".join(KNOWN_BENCHES),
                    help=f"comma-separated subset of {', '.join(KNOWN_BENCHES)}")
    ap.add_argument("--quick", action="store_true", help="small sizes (CI)")
    ap.add_argument("--large", action="store_true",
                    help="scaling bench only: drive the fused sharded+chunked "
                         "pipeline at 100k/500k/1M points (slow — minutes to "
                         "hours; never part of --quick CI)")
    ap.add_argument("--n", type=int, default=None, help="points for step bench")
    ap.add_argument("--scale", type=float, default=None, help="e2e dataset scale")
    ap.add_argument("--out-dir", default=str(REPO_ROOT),
                    help="directory for the BENCH_<n>.json artifact")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing the BENCH_<n>.json artifact")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="enable span tracing; write Chrome-trace JSON to "
                         "PATH (default: <out-dir>/trace_bench.json)")
    ap.add_argument("--compare", default=None, metavar="PREV.json",
                    help="diff this run against a previous BENCH_<n>.json "
                         "and exit nonzero on regression")
    ap.add_argument("--compare-threshold", type=float, default=0.25,
                    help="relative slowdown that fails --compare "
                         "(default: 0.25 = 25%%)")
    args = ap.parse_args()
    benches = [b.strip() for b in args.bench.split(",") if b.strip()]
    unknown = [b for b in benches if b not in KNOWN_BENCHES]
    if not benches:
        ap.error("--bench selected no benchmarks")
    if unknown:
        ap.error(
            f"unknown bench name(s): {', '.join(unknown)} "
            f"(known: {', '.join(KNOWN_BENCHES)})"
        )

    tracer = None
    if args.trace is not None:
        from repro import obs
        tracer = obs.set_tracer(obs.Tracer())

    t0 = time.time()
    print("name,us_per_call,derived")

    if "steps" in benches:
        from benchmarks import bench_steps
        bench_steps.run(n=args.n or (4000 if args.quick else 20000))
    if "accuracy" in benches:
        from benchmarks import bench_accuracy
        bench_accuracy.run(n=600 if args.quick else 1500,
                           n_iter=120 if args.quick else 300)
    if "scaling" in benches:
        from benchmarks import bench_scaling
        sizes = (1000, 2000, 4000) if args.quick else (2000, 4000, 8000, 16000, 32000)
        bench_scaling.run(sizes=sizes, exact_cap=2000 if args.quick else 8000)
        if args.large and not args.quick:
            bench_scaling.run_large()
        elif args.large:
            print("# --large ignored under --quick", file=sys.stderr)
    if "e2e" in benches:
        from benchmarks import bench_e2e
        bench_e2e.run(n_iter=60 if args.quick else 250,
                      scale=args.scale or (0.15 if args.quick else 1.0))
    if "knn" in benches:
        from benchmarks import bench_knn
        bench_knn.run(sizes=(2000, 5000) if args.quick else (2000, 10000, 50000),
                      k=15 if args.quick else 30)

    wall_s = time.time() - t0
    print(f"# total_bench_wall_s,{wall_s:.1f},", file=sys.stderr)
    if not args.no_json:
        from benchmarks.common import write_bench_json
        path = write_bench_json(
            args.out_dir, benches=benches, argv=sys.argv[1:], wall_s=wall_s
        )
        print(f"# wrote {path}", file=sys.stderr)
    if tracer is not None:
        trace_path = args.trace or str(
            pathlib.Path(args.out_dir) / "trace_bench.json")
        tracer.to_chrome_trace(trace_path, process_name="benchmarks")
        print(f"# wrote Chrome trace ({len(tracer.spans)} spans) to "
              f"{trace_path}", file=sys.stderr)
    if args.compare is not None:
        sys.exit(run_compare_gate(args.compare, args.compare_threshold))


def run_compare_gate(prev_path: str, threshold: float) -> int:
    """Diff the rows of this run against ``prev_path``; 1 on regression.

    Factored out of :func:`main` so the regression-exit path is unit-testable
    without re-running the benches (tests/test_bench_compare.py).
    """
    from benchmarks.common import ROWS, compare_runs, load_bench_json
    prev = load_bench_json(prev_path)
    lines, regressions = compare_runs(prev, ROWS, threshold=threshold)
    print(f"# --compare vs {prev_path} "
          f"(commit {prev.get('git', {}).get('commit', 'unknown')[:12]})",
          file=sys.stderr)
    for line in lines:
        print(line, file=sys.stderr)
    if regressions:
        print(f"# FAIL: {len(regressions)} bench(es) regressed more than "
              f"{threshold:.0%}:", file=sys.stderr)
        for name, p, us, delta in regressions:
            print(f"#   {name}: {p:.1f}us -> {us:.1f}us ({delta:+.1%})",
                  file=sys.stderr)
        return 1
    print("# compare OK: no bench regressed beyond threshold", file=sys.stderr)
    return 0


if __name__ == "__main__":
    main()
