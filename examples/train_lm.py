"""Train a ~100M-param dense LM with the full substrate (AdamW, async
checkpoints, deterministic pipeline, restart-safe).

    PYTHONPATH=src python examples/train_lm.py --steps 300 --d_model 768

Defaults give a ~100M-parameter model (12L x 768d, 32k vocab). On this CPU
container use --steps 20 --d_model 256 for a smoke-scale run; the same
script drives pod-scale training through launch/train.py's mesh wiring.
"""
import argparse
import dataclasses

from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenPipeline
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d_model", type=int, default=768)
    ap.add_argument("--n_layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=32768)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt_dir", default="runs/train_lm")
    args = ap.parse_args()

    cfg = ArchConfig(
        name="lm100m", family="dense", n_layers=args.n_layers,
        d_model=args.d_model, n_heads=args.d_model // 64,
        n_kv_heads=max(1, args.d_model // 128), d_ff=4 * args.d_model,
        vocab_size=args.vocab, head_dim=64, compute_dtype="float32",
    )
    from repro.configs.base import param_count
    total, _ = param_count(cfg)
    print(f"model: {total/1e6:.1f}M params")

    model = build_model(cfg)
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)
    trainer = Trainer(
        model, pipe,
        TrainerConfig(n_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      ckpt_dir=args.ckpt_dir, log_every=10),
        AdamWConfig(learning_rate=args.lr, warmup_steps=min(100, args.steps // 3)),
    )
    trainer.run(callback=lambda s, m: print(
        f"step {s:5d}  loss {m['loss_mean']:.4f}  gnorm {m['grad_norm']:.2f}  "
        f"{m['wall_s']:.1f}s"))


if __name__ == "__main__":
    main()
