"""Quickstart: Barnes-Hut t-SNE on the digits-size dataset.

    PYTHONPATH=src python examples/quickstart.py [--n 1797] [--iters 500]

Produces embedding.npy + prints the KL trajectory — the 30-second tour of
the public API (TsneConfig / run_tsne).
"""
import argparse

import numpy as np

from repro.core.tsne import TsneConfig, run_tsne
from repro.data.datasets import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1797)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--perplexity", type=float, default=30.0)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--out", default="embedding.npy")
    args = ap.parse_args()

    x, labels = make_dataset("digits", n=args.n)
    cfg = TsneConfig(perplexity=args.perplexity, theta=args.theta,
                     n_iter=args.iters)
    res = run_tsne(x, cfg, callback=lambda it, kl: print(f"iter {it:5d}  KL {kl:.4f}"))
    np.save(args.out, res.y)
    print(f"\ntimings: {res.timings}")
    print(f"final KL = {res.kl:.4f}; embedding -> {args.out}")

    # quick quality readout: mean intra/inter cluster distance ratio
    y = res.y
    cents = np.stack([y[labels == c].mean(0) for c in np.unique(labels)])
    intra = np.mean([np.linalg.norm(y[labels == c] - cents[i], axis=1).mean()
                     for i, c in enumerate(np.unique(labels))])
    dists = [np.linalg.norm(a - b) for i, a in enumerate(cents) for b in cents[i + 1:]]
    print(f"cluster separation: intra {intra:.2f} vs inter {np.mean(dists):.2f}")


if __name__ == "__main__":
    main()
