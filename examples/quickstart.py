"""Quickstart: t-SNE through the sklearn-compatible estimator API.

    PYTHONPATH=src python examples/quickstart.py [--n 1797] [--iters 500]
        [--method exact|barnes_hut|fft]

Produces embedding.npy + prints the KL trajectory — the 30-second tour of
the public API (repro.api.TSNE with a pluggable gradient backend).
"""
import argparse

import numpy as np

from repro.api import TSNE
from repro.data.datasets import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1797)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--perplexity", type=float, default=30.0)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--method", default="barnes_hut")
    ap.add_argument("--out", default="embedding.npy")
    args = ap.parse_args()

    x, labels = make_dataset("digits", n=args.n)
    est = TSNE(
        method=args.method, perplexity=args.perplexity, angle=args.theta,
        n_iter=args.iters, random_state=0,
        callbacks=[lambda s: print(
            f"iter {s.iteration:5d}  KL {s.kl:.4f}  |grad| {s.grad_norm:.2e}")],
    )
    y = est.fit_transform(x)
    np.save(args.out, y)
    print(f"\ntimings: {est.timings_}")
    print(f"final KL = {est.kl_divergence_:.4f} after {est.n_iter_} iters; "
          f"embedding -> {args.out}")

    # quick quality readout: mean intra/inter cluster distance ratio
    cents = np.stack([y[labels == c].mean(0) for c in np.unique(labels)])
    intra = np.mean([np.linalg.norm(y[labels == c] - cents[i], axis=1).mean()
                     for i, c in enumerate(np.unique(labels))])
    dists = [np.linalg.norm(a - b) for i, a in enumerate(cents) for b in cents[i + 1:]]
    print(f"cluster separation: intra {intra:.2f} vs inter {np.mean(dists):.2f}")


if __name__ == "__main__":
    main()
