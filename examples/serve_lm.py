"""Serve a model with the continuous-batching engine.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6_3b --requests 8
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek_7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max_new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("text archs only in this example")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, batch_slots=args.slots, max_seq=128)
    for r in range(args.requests):
        eng.submit(Request(rid=r, prompt=[(r * 7 + i) % cfg.vocab_size for i in range(1, 6)],
                           max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = eng.run(params)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, {args.slots} slots)")
    for r in done[:4]:
        print(f"  req {r.rid}: {r.generated}")


if __name__ == "__main__":
    main()
