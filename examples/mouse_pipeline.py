"""End-to-end driver matching the paper's headline experiment: t-SNE on the
mouse-brain-cell dataset (1.3M cells x 20 PCA components).

    PYTHONPATH=src python examples/mouse_pipeline.py --n 50000 --iters 1000
    PYTHONPATH=src python examples/mouse_pipeline.py \
        --n 1000000 --shards 4 --chunk-size 100000 --method fft \
        --iters 300 --bench-out .

This is the paper's kind of workload end-to-end: KNN -> BSP -> symmetrize ->
gradient-descent iterations with per-stage timings (paper Fig. 1b /
Table 5).  --n scales the subsample; the full 1291337 points run with
--n 1291337.

Memory envelope: at large --n the pipeline is *chunk/shard-bounded, not
N-bounded*.  The KNN stage defaults to the ``sharded`` backend (per-shard
rp_forest + candidate ring) above ``--n`` 200k, whose transients are
O(block_rows * candidates) per shard; the perplexity search and the ELL
symmetrization stream over ``--chunk-size`` row slices, so beyond the
O(N*K) neighbor graph itself (the product) nothing larger than
O(chunk * K) is ever live.  Nothing in the pipeline materializes anything
O(N^2).  Pass --neighbor_method exact to get the brute-force scan back for
oracle comparisons at small --n.

``--shards S`` forces S host devices (the flag is translated to
``XLA_FLAGS=--xla_force_host_platform_device_count=S`` before jax loads;
on real multi-device hardware the visible devices are used as-is).
Checkpointing (--ckpt_dir) makes multi-hour full-size runs restartable;
``--bench-out DIR`` records the run as the next ``BENCH_<n>.json`` artifact
with the per-phase breakdown (docs/BENCHMARKS.md schema).
"""
import argparse
import os
import pathlib
import sys
import time


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50000)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--perplexity", type=float, default=30.0)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--method", default="barnes_hut",
                    help="gradient backend: barnes_hut | fft | exact | ...")
    ap.add_argument("--neighbor_method", default="auto",
                    help="auto (sharded above 200k points, else rp_forest) | "
                         "exact | rp_forest | nn_descent | sharded | any "
                         "registered name")
    ap.add_argument("--n_neighbors", type=int, default=None,
                    help="KNN degree (default: 3 * perplexity)")
    ap.add_argument("--shards", type=int, default=0,
                    help="device shards for the sharded KNN ring (0 = all "
                         "visible devices; >1 forces that many host devices)")
    ap.add_argument("--chunk-size", type=int, default=0,
                    help="rows per BSP/symmetrize slice (0 = auto: 100k "
                         "chunks above 200k points, unchunked below)")
    ap.add_argument("--kl_every", type=int, default=50)
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=200)
    ap.add_argument("--out", default="mouse_embedding.npy")
    ap.add_argument("--bench-out", default="",
                    help="directory for a BENCH_<n>.json artifact of this "
                         "run (empty = don't write one)")
    return ap.parse_args()


AUTO_SCALE_N = 200_000      # above this, default to sharded KNN + chunking
AUTO_CHUNK = 100_000


def main():
    args = parse_args()
    if args.shards > 1 and "XLA_FLAGS" not in os.environ:
        # must land before jax initializes its backends
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.shards}"
        )

    import jax.numpy as jnp
    import numpy as np

    from repro.api import make_backend
    from repro.core.tsne import TsneConfig, init_state, preprocess, tsne_step
    from repro.data.datasets import make_dataset

    neighbor_method = args.neighbor_method
    if neighbor_method == "auto":
        neighbor_method = "sharded" if args.n >= AUTO_SCALE_N else "rp_forest"
    chunk = args.chunk_size or (AUTO_CHUNK if args.n >= AUTO_SCALE_N else None)

    print(f"generating mouse-like dataset: {args.n} cells x 20 components")
    x, _ = make_dataset("mouse_1p3m", n=args.n)
    cfg = TsneConfig(perplexity=args.perplexity, theta=args.theta,
                     n_iter=args.iters, neighbor_method=neighbor_method,
                     n_neighbors=args.n_neighbors,
                     chunk_size=chunk,
                     knn_shards=args.shards or None,
                     method=args.method)

    t0 = time.perf_counter()
    graph, timings = preprocess(jnp.asarray(x), cfg)
    print(f"KNN[{timings['neighbor_method']}, k={timings['n_neighbors']}] "
          f"{timings['knn']:.1f}s  BSP {timings['bsp']:.1f}s  "
          f"symmetrize {timings['symmetrize']:.1f}s  "
          f"(chunk_size={timings['chunk_size']})")

    state = init_state(args.n, cfg)
    ckpt = None
    start = 0
    if args.ckpt_dir:
        from repro.train.checkpoint import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            state, start = ckpt.restore(state)
            print(f"resumed from iteration {start}")

    lr = cfg.resolve_lr(args.n)
    backend = make_backend(cfg.method, cfg, args.n)
    t_gd = time.perf_counter()
    kl = float("nan")
    for it in range(start, args.iters):
        exag = cfg.early_exaggeration if it < cfg.exaggeration_iters else 1.0
        mom = cfg.momentum_initial if it < cfg.momentum_switch_iter else cfg.momentum_final
        state, stats = tsne_step(
            state, graph, jnp.asarray(exag, jnp.float32),
            jnp.asarray(mom, jnp.float32),
            backend=backend, lr=lr, min_gain=cfg.min_gain)
        if (it + 1) % args.kl_every == 0 or it == args.iters - 1:
            kl = float(stats.kl)
            print(f"iter {it+1:5d}  KL {kl:.4f}  "
                  f"max_traversal {int(stats.max_traversal)}  "
                  f"{(time.perf_counter()-t_gd)/(it+1-start)*1000:.0f} ms/iter")
        if ckpt is not None and (it + 1) % args.ckpt_every == 0:
            ckpt.save(it + 1, state)
    if ckpt is not None:
        ckpt.wait()
    state.y.block_until_ready()
    timings["gradient_descent"] = time.perf_counter() - t_gd
    total_s = time.perf_counter() - t0
    np.save(args.out, np.asarray(state.y))
    print(f"total {total_s:.1f}s; embedding -> {args.out}")

    if args.bench_out:
        # benchmarks/ is a repo-root package, not installed — make it
        # importable no matter where this script was launched from
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
        from benchmarks.common import emit, record_phases, write_bench_json
        run_name = f"mouse_pipeline_n{args.n}_{cfg.method}"
        emit(run_name, total_s * 1e6,
             f"kl={kl:.4f} iters={args.iters} knn={timings['neighbor_method']} "
             f"shards={args.shards or 'all'} chunk={timings['chunk_size']}")
        record_phases(run_name, timings)
        path = write_bench_json(args.bench_out, benches=["mouse_pipeline"],
                                argv=sys.argv[1:], wall_s=total_s)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
