"""End-to-end driver matching the paper's headline experiment: t-SNE on the
mouse-brain-cell dataset (1.3M cells x 20 PCA components).

    PYTHONPATH=src python examples/mouse_pipeline.py --n 50000 --iters 1000

This is the paper's kind of workload end-to-end: KNN -> BSP -> symmetrize ->
1000 gradient-descent iterations with per-stage timings (paper Fig. 1b /
Table 5).  --n scales the subsample (the paper also benchmarks a 1M-cell
subsample); the full 1291337 points run with --n 1291337 given time.
The KNN stage defaults to the ``rp_forest`` approximate backend — at this
dataset's scale the exact O(N²·D) scan dominates end-to-end time (pass
--neighbor_method exact to get it back).  Checkpointing (--ckpt_dir) makes
multi-hour full-size runs restartable.
"""
import argparse
import pathlib
import time

import numpy as np

from repro.api import make_backend
from repro.core.tsne import TsneConfig, init_state, preprocess, tsne_step
from repro.data.datasets import make_dataset

import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50000)
    ap.add_argument("--iters", type=int, default=1000)
    ap.add_argument("--perplexity", type=float, default=30.0)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--neighbor_method", default="rp_forest",
                    help="exact | rp_forest | nn_descent | any registered name")
    ap.add_argument("--n_neighbors", type=int, default=None,
                    help="KNN degree (default: 3 * perplexity)")
    ap.add_argument("--ckpt_dir", default="")
    ap.add_argument("--ckpt_every", type=int, default=200)
    ap.add_argument("--out", default="mouse_embedding.npy")
    args = ap.parse_args()

    print(f"generating mouse-like dataset: {args.n} cells x 20 components")
    x, _ = make_dataset("mouse_1p3m", n=args.n)
    cfg = TsneConfig(perplexity=args.perplexity, theta=args.theta,
                     n_iter=args.iters, neighbor_method=args.neighbor_method,
                     n_neighbors=args.n_neighbors)

    t0 = time.perf_counter()
    graph, timings = preprocess(jnp.asarray(x), cfg)
    print(f"KNN[{timings['neighbor_method']}, k={timings['n_neighbors']}] "
          f"{timings['knn']:.1f}s  BSP {timings['bsp']:.1f}s  "
          f"symmetrize {timings['symmetrize']:.1f}s")

    state = init_state(args.n, cfg)
    ckpt = None
    start = 0
    if args.ckpt_dir:
        from repro.train.checkpoint import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            state, start = ckpt.restore(state)
            print(f"resumed from iteration {start}")

    lr = cfg.resolve_lr(args.n)
    backend = make_backend(cfg.method, cfg, args.n)
    t_gd = time.perf_counter()
    for it in range(start, args.iters):
        exag = cfg.early_exaggeration if it < cfg.exaggeration_iters else 1.0
        mom = cfg.momentum_initial if it < cfg.momentum_switch_iter else cfg.momentum_final
        state, stats = tsne_step(
            state, graph, jnp.asarray(exag, jnp.float32),
            jnp.asarray(mom, jnp.float32),
            backend=backend, lr=lr, min_gain=cfg.min_gain)
        if (it + 1) % 50 == 0:
            print(f"iter {it+1:5d}  KL {float(stats.kl):.4f}  "
                  f"max_traversal {int(stats.max_traversal)}  "
                  f"{(time.perf_counter()-t_gd)/(it+1-start)*1000:.0f} ms/iter")
        if ckpt is not None and (it + 1) % args.ckpt_every == 0:
            ckpt.save(it + 1, state)
    if ckpt is not None:
        ckpt.wait()
    np.save(args.out, np.asarray(state.y))
    print(f"total {time.perf_counter()-t0:.1f}s; embedding -> {args.out}")


if __name__ == "__main__":
    main()
