"""LM representation atlas: run an assigned architecture, harvest hidden
states, embed them with Barnes-Hut t-SNE — then GROW the atlas point by
point through the continuous-batching embedding service.

    PYTHONPATH=src python examples/lm_embedding_atlas.py --arch deepseek_7b

This is the integration the paper motivates (visualizing high-dimensional
representations at scale — scRNA-seq there, LM token states here) plus the
deployment shape the ROADMAP names: a live embedding view over a corpus
that keeps growing.  A reference corpus is fitted once; every later state
is a single-point transform request drained through the fixed slot pool —
no refit, frozen reference embedding, per-request latency stats.
"""
import argparse

import jax
import numpy as np

from repro.api import TSNE, EmbeddingService, TransformRequest
from repro.configs import ARCH_IDS, get_reduced_config
from repro.models.model import build_model


def domain_separation(y, labels, n_domains=4):
    """Mean intra-domain spread vs mean inter-centroid distance."""
    cents = np.stack([y[labels == d].mean(0) for d in range(n_domains)])
    intra = np.mean([np.linalg.norm(y[labels == d] - cents[d], axis=1).mean()
                     for d in range(n_domains)])
    inter = np.mean([np.linalg.norm(a - b)
                     for i, a in enumerate(cents) for b in cents[i + 1:]])
    return intra, inter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek_7b")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--grow", type=int, default=32,
                    help="states held out of the fit and grown point-by-point")
    ap.add_argument("--out", default="atlas.npy")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("pick a text arch for the atlas example")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # harvest last-token hidden states via prefill logits' pre-softmax space:
    # embed tokens from distinct synthetic "domains" (different ranges)
    states, labels = [], []
    prefill = jax.jit(model.prefill)
    for dom in range(4):
        lo = dom * (cfg.vocab_size // 4)
        hi = lo + cfg.vocab_size // 8
        for b in range(args.batches):
            toks = jax.random.randint(jax.random.PRNGKey(dom * 100 + b),
                                      (4, args.seq), lo, hi)
            logits = prefill(params, {"tokens": toks})
            states.append(np.asarray(logits, np.float32))
            labels.extend([dom] * logits.shape[0])
    x = np.concatenate(states, axis=0)
    # project to 50 dims (the usual PCA-before-t-SNE step, power iteration-free)
    rng = np.random.default_rng(0)
    x = (x - x.mean(0)) @ rng.normal(size=(x.shape[1], 50)).astype(np.float32) / np.sqrt(x.shape[1])
    labels = np.asarray(labels)

    # interleave domains in the held-out tail so growth mixes clusters
    perm = rng.permutation(x.shape[0])
    x, labels = x[perm], labels[perm]
    n_grow = min(args.grow, x.shape[0] // 4)
    x_fit, x_new = x[:-n_grow], x[-n_grow:]

    print(f"fitting atlas on {x_fit.shape[0]} states from {args.arch} "
          f"(holding out {n_grow} to grow through the service)")
    est = TSNE(perplexity=10.0, n_iter=args.iters, kl_every=100,
               random_state=0,
               backend_options=dict(exaggeration_iters=100,
                                    momentum_switch_iter=100))
    est.fit(x_fit)

    service = EmbeddingService(slots=args.slots)
    service.add_model("atlas", est)
    for i, xi in enumerate(x_new):
        service.submit(TransformRequest(rid=i, dataset="atlas", x=xi))
    done = service.run()
    assert len(done) == n_grow
    y_new = np.stack([r.y for r in sorted(done, key=lambda r: r.rid)])
    y = np.concatenate([est.embedding_, y_new], axis=0)
    np.save(args.out, y)

    # domains with disjoint vocab ranges should separate — for the fitted
    # points AND the points grown through the service
    intra, inter = domain_separation(y, labels)
    intra_new, inter_new = domain_separation(y_new, labels[-n_grow:])
    s = service.stats()
    print(f"KL={est.kl_divergence_:.3f}  intra={intra:.2f}  inter={inter:.2f}"
          f"  (grown-only: intra={intra_new:.2f} inter={inter_new:.2f})"
          f"  -> {args.out}")
    print(f"service: {s['completed']} requests, {s['ticks']} ticks, "
          f"mean {s['steps_mean']:.0f} steps, "
          f"p50 latency {s['latency_s_p50'] * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
