"""LM representation atlas: run an assigned architecture, harvest hidden
states, embed them with the distributed Barnes-Hut t-SNE.

    PYTHONPATH=src python examples/lm_embedding_atlas.py --arch deepseek_7b

This is the integration the paper motivates (visualizing high-dimensional
representations at scale — scRNA-seq there, LM token states here): the same
framework trains/serves the model *and* provides the analysis stage.
Reduced configs keep it CPU-sized; on a pod the t-SNE step shards points
over the data axis (repro.core.distributed).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced_config
from repro.core.tsne import TsneConfig, run_tsne
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek_7b")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--out", default="atlas.npy")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("pick a text arch for the atlas example")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # harvest last-token hidden states via prefill logits' pre-softmax space:
    # embed tokens from distinct synthetic "domains" (different ranges)
    states, labels = [], []
    prefill = jax.jit(model.prefill)
    for dom in range(4):
        lo = dom * (cfg.vocab_size // 4)
        hi = lo + cfg.vocab_size // 8
        for b in range(args.batches):
            toks = jax.random.randint(jax.random.PRNGKey(dom * 100 + b),
                                      (4, args.seq), lo, hi)
            logits = prefill(params, {"tokens": toks})
            states.append(np.asarray(logits, np.float32))
            labels.extend([dom] * logits.shape[0])
    x = np.concatenate(states, axis=0)
    # project to 50 dims (the usual PCA-before-t-SNE step, power iteration-free)
    rng = np.random.default_rng(0)
    x = (x - x.mean(0)) @ rng.normal(size=(x.shape[1], 50)).astype(np.float32) / np.sqrt(x.shape[1])
    labels = np.asarray(labels)

    print(f"embedding {x.shape[0]} states from {args.arch}")
    res = run_tsne(x, TsneConfig(perplexity=10.0, n_iter=args.iters,
                                 exaggeration_iters=100, momentum_switch_iter=100))
    np.save(args.out, res.y)
    # domains with disjoint vocab ranges should separate
    y = res.y
    cents = np.stack([y[labels == d].mean(0) for d in range(4)])
    intra = np.mean([np.linalg.norm(y[labels == d] - cents[d], axis=1).mean() for d in range(4)])
    inter = np.mean([np.linalg.norm(a - b) for i, a in enumerate(cents) for b in cents[i + 1:]])
    print(f"KL={res.kl:.3f}  intra={intra:.2f}  inter={inter:.2f}  -> {args.out}")


if __name__ == "__main__":
    main()
