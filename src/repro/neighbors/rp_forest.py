"""Random-projection tree forest (Annoy / FIt-SNE-style approximate KNN).

Each tree recursively halves the point set ``depth`` times with a median
hyperplane split — expressed as one multi-key ``lax.sort`` per level over
(segment id, projection), so the whole forest build is a handful of sorts
and matmuls, fully jittable with static shapes.  Leaves then hold
``ceil(N / 2^depth)`` points; within each leaf we score all pairs exactly
and keep the top-k, and the per-tree graphs are merged with duplicate
dropping.  Recall grows with ``n_trees`` and ``leaf_size``; an optional
``refine_iters`` polish runs NN-descent over the forest output.

The same forest doubles as an out-of-sample query index: the build records
each level's median split threshold, so a new point routes down every tree
(project, compare, descend — ``depth`` dot products per tree) to a leaf
whose members are scored exactly and merged across trees.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import ClassVar

import jax
import jax.numpy as jnp
from jax import lax

from repro.neighbors._candidates import (
    candidate_sq_dists, merge_topk, seed_graph,
)
from repro.neighbors.base import (
    register_neighbor_backend, validate_k, validate_query_k,
)


@functools.partial(jax.jit, static_argnames=("depth", "n_pad"))
def _build_tree(
    x: jax.Array, key: jax.Array, depth: int, n_pad: int
) -> tuple[jax.Array, jax.Array, tuple[jax.Array, ...]]:
    """One tree: leaf membership + the structure needed to route queries.

    Level ``l`` sorts each of the 2^l equal-length segments by the points'
    projection onto that level's random direction; halving sorted segments
    is exactly a median split, so the tree stays perfectly balanced.  Pads
    project to +inf and sink to the high side of every split.

    Returns ``(leaves [2^depth, leaf_size] point indices (pads hold
    idx >= N), dirs [depth, D] hyperplane directions, thrs)`` where
    ``thrs[l] [2^l]`` is the split value of each level-``l`` node — the
    midpoint of the two projections straddling the median, so a query goes
    right iff its projection exceeds it.
    """
    n, d = x.shape
    dirs = jax.random.normal(key, (depth, d), x.dtype)
    proj = x @ dirs.T if depth else None             # [N, depth]
    order = jnp.arange(n_pad, dtype=jnp.int32)
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    pos = jnp.arange(n_pad, dtype=jnp.int32)
    thrs = []
    for level in range(depth):
        seg_len = n_pad >> level
        seg = pos // seg_len
        p = jnp.where(order < n, proj[jnp.clip(order, 0, n - 1), level], big)
        _, p_s, order = lax.sort((seg, p, order), num_keys=2)
        half = seg_len >> 1
        p2 = p_s.reshape(1 << level, seg_len)
        thrs.append(0.5 * (p2[:, half - 1] + p2[:, half]))
    return order.reshape(1 << depth, n_pad >> depth), dirs, tuple(thrs)


@functools.partial(jax.jit, static_argnames=("k", "n_pad"))
def _leaf_topk(x: jax.Array, leaves: jax.Array, k: int, n_pad: int):
    """Exact top-k within each leaf's candidate set, scattered per point.

    Returns ``(idx [n_pad, kk], d2 [n_pad, kk])`` with ``kk = min(k, S-1)``;
    rows >= N are pad slots the caller slices off.
    """
    n = x.shape[0]
    n_leaves, s = leaves.shape
    kk = min(k, s - 1)
    safe = jnp.clip(leaves, 0, n - 1)
    xb = x[safe]                                     # [L, S, D]
    sqn = jnp.sum(xb * xb, axis=2)
    d2 = sqn[:, :, None] + sqn[:, None, :] - 2.0 * jnp.einsum(
        "lsd,ltd->lst", xb, xb
    )
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    pad_col = (leaves >= n)[:, None, :]
    self_col = jnp.eye(s, dtype=bool)[None]
    d2 = jnp.where(pad_col | self_col, big, d2)
    neg_top, argtop = lax.top_k(-d2, kk)             # [L, S, kk]
    glob = jnp.take_along_axis(
        jnp.broadcast_to(leaves[:, None, :], (n_leaves, s, s)), argtop, axis=2
    )
    out_i = jnp.zeros((n_pad, kk), jnp.int32).at[leaves.reshape(-1)].set(
        glob.reshape(-1, kk)
    )
    out_d = jnp.zeros((n_pad, kk), x.dtype).at[leaves.reshape(-1)].set(
        jnp.maximum(-neg_top, 0.0).reshape(-1, kk)
    )
    return out_i, out_d


@functools.partial(
    jax.jit, static_argnames=("k", "n_trees", "depth", "block_rows")
)
def rp_forest_knn(
    x: jax.Array,
    k: int,
    *,
    n_trees: int = 8,
    depth: int = 4,
    seed: int = 0,
    block_rows: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Approximate KNN via ``n_trees`` RP trees of ``depth`` median splits."""
    n = x.shape[0]
    leaf = -(-n // (1 << depth))                     # ceil(n / 2^depth)
    n_pad = leaf << depth
    key = jax.random.PRNGKey(seed)
    best_i, best_d = seed_graph(x, k, jax.random.fold_in(key, n_trees),
                                block_rows=block_rows)
    # collect every tree's within-leaf top-k, then fold once: a single wide
    # dedup/top-k merge beats n_trees narrow ones (the sort dominates)
    cand_i, cand_d = [], []
    for t in range(n_trees):
        leaves, _, _ = _build_tree(x, jax.random.fold_in(key, t), depth, n_pad)
        ti, td = _leaf_topk(x, leaves, k, n_pad)
        cand_i.append(ti[:n])
        cand_d.append(td[:n])
    return merge_topk(
        best_i, best_d,
        jnp.concatenate(cand_i, axis=1), jnp.concatenate(cand_d, axis=1),
        k, n,
    )


@functools.partial(jax.jit, static_argnames=("n_trees", "depth", "n_pad"))
def build_forest_index(
    x: jax.Array, n_trees: int, depth: int, n_pad: int, seed: int = 0
):
    """Stack every tree's routing structure: the frozen query-side forest.

    Returns ``(leaves [T, 2^depth, leaf_size], dirs [T, depth, D],
    thrs)`` with ``thrs[l] [T, 2^l]`` — the same trees (same PRNG folds)
    ``rp_forest_knn`` builds, so queries descend the forest the fitted
    points were bucketed by.
    """
    key = jax.random.PRNGKey(seed)
    leaves, dirs, thrs = [], [], []
    for t in range(n_trees):
        lv, dr, th = _build_tree(x, jax.random.fold_in(key, t), depth, n_pad)
        leaves.append(lv)
        dirs.append(dr)
        thrs.append(th)
    return (
        jnp.stack(leaves),
        jnp.stack(dirs),
        tuple(jnp.stack([th[l] for th in thrs]) for l in range(depth)),
    )


def route_to_leaves(
    leaves: jax.Array,
    dirs: jax.Array,
    thrs: tuple[jax.Array, ...],
    q: jax.Array,
) -> jax.Array:
    """Descend every tree with each query point; gather its leaf's members.

    q [M, D] -> cand [M, n_trees * leaf_size] reference-set indices (entries
    >= the fitted N are leaf padding the caller must mask).  This is the
    routing half of :func:`forest_query`, shared with the distributed
    candidate ring (``core/distributed.ring_knn_approx``) where scoring and
    merging happen against a remote shard's running top-k.
    """
    n_trees, _, leaf_size = leaves.shape
    depth = dirs.shape[1]
    m = q.shape[0]
    tree_ids = jnp.arange(n_trees, dtype=jnp.int32)[None, :]      # [1, T]
    node = jnp.zeros((m, n_trees), jnp.int32)
    if depth:
        proj = jnp.einsum("md,tld->mtl", q, dirs)                 # [M, T, depth]
        for level in range(depth):
            thr = thrs[level][tree_ids, node]                     # [M, T]
            node = node * 2 + (proj[:, :, level] > thr).astype(jnp.int32)
    return leaves[tree_ids, node].reshape(m, n_trees * leaf_size)


@functools.partial(jax.jit, static_argnames=("k", "block_rows"))
def forest_query(
    x_ref: jax.Array,
    leaves: jax.Array,
    dirs: jax.Array,
    thrs: tuple[jax.Array, ...],
    q: jax.Array,
    k: int,
    block_rows: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Route queries down every tree, score leaf members exactly, merge.

    q [M, D] -> (idx [M, k] into x_ref, d2 [M, k]).  A deterministic seed
    row (the first k reference points, scored exactly) guarantees k valid
    distinct indices even if the forest candidates collapse to duplicates.
    """
    n = x_ref.shape[0]
    m = q.shape[0]
    cand = route_to_leaves(leaves, dirs, thrs, q)
    cd = candidate_sq_dists(x_ref, cand, block_rows=block_rows, q=q)
    base_i = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None], (m, k))
    base_d = candidate_sq_dists(x_ref, base_i, block_rows=block_rows, q=q)
    return merge_topk(base_i, base_d, cand, cd, k, n, exclude_self=False)


@dataclasses.dataclass(frozen=True, eq=False)
class RPForestIndex:
    """Frozen RP forest over a fitted reference set, ready for queries."""

    x_ref: jax.Array
    leaves: jax.Array                      # [T, 2^depth, leaf_size]
    dirs: jax.Array                        # [T, depth, D]
    thrs: tuple[jax.Array, ...]            # level l: [T, 2^l]
    block_rows: int = 512

    @property
    def n_reference(self) -> int:
        return int(self.x_ref.shape[0])

    def query(self, x_new: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_query_k(self.n_reference, k)
        return forest_query(
            self.x_ref, self.leaves, self.dirs, self.thrs,
            x_new.astype(self.x_ref.dtype), k, block_rows=self.block_rows,
        )


@dataclasses.dataclass(frozen=True)
class RPForestNeighbors:
    """Forest of random-projection trees; ``refine_iters`` adds NN-descent
    polish passes over the forest graph (see ``nn_descent.py``)."""

    name: ClassVar[str] = "rp_forest"
    n_trees: int = 8
    leaf_size: int = 64
    refine_iters: int = 2
    seed: int = 0
    block_rows: int = 512

    def resolve_depth(self, n: int, k: int) -> int:
        """Deepest split keeping leaves >= max(leaf_size, k+1) points, so a
        single leaf can supply a full top-k row."""
        leaf = max(self.leaf_size, k + 1)
        return max(0, int(math.floor(math.log2(max(1.0, n / leaf)))))

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_k(x.shape[0], k)
        idx, d2 = rp_forest_knn(
            x, k,
            n_trees=self.n_trees,
            depth=self.resolve_depth(x.shape[0], k),
            seed=self.seed,
            block_rows=self.block_rows,
        )
        if self.refine_iters > 0:
            from repro.neighbors.nn_descent import nn_descent_knn
            # offset the seed: refine rounds must not replay the PRNG keys
            # that drew the tree hyperplanes (fold_in shares the int domain)
            idx, d2 = nn_descent_knn(
                x, k, init=(idx, d2), n_iters=self.refine_iters,
                seed=self.seed + 1, block_rows=self.block_rows,
            )
        return idx, d2

    def build_index(self, x: jax.Array) -> RPForestIndex:
        """Build (once) the forest a fitted reference set is bucketed by.

        Depth matches the ``neighbors`` heuristic with ``k = leaf_size - 1``
        so leaves keep >= ``leaf_size`` points regardless of later query k;
        ``validate_query_k`` bounds k at query time.
        """
        x = jnp.asarray(x)
        n = int(x.shape[0])
        depth = self.resolve_depth(n, max(1, min(self.leaf_size, n) - 1))
        leaf = -(-n // (1 << depth))
        n_pad = leaf << depth
        leaves, dirs, thrs = build_forest_index(
            x, self.n_trees, depth, n_pad, seed=self.seed
        )
        return RPForestIndex(
            x_ref=x, leaves=leaves, dirs=dirs, thrs=thrs,
            block_rows=self.block_rows,
        )


register_neighbor_backend("rp_forest", RPForestNeighbors)
