"""Random-projection tree forest (Annoy / FIt-SNE-style approximate KNN).

Each tree recursively halves the point set ``depth`` times with a median
hyperplane split — expressed as one multi-key ``lax.sort`` per level over
(segment id, projection), so the whole forest build is a handful of sorts
and matmuls, fully jittable with static shapes.  Leaves then hold
``ceil(N / 2^depth)`` points; within each leaf we score all pairs exactly
and keep the top-k, and the per-tree graphs are merged with duplicate
dropping.  Recall grows with ``n_trees`` and ``leaf_size``; an optional
``refine_iters`` polish runs NN-descent over the forest output.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import ClassVar

import jax
import jax.numpy as jnp
from jax import lax

from repro.neighbors._candidates import merge_topk, seed_graph
from repro.neighbors.base import register_neighbor_backend, validate_k


@functools.partial(jax.jit, static_argnames=("depth", "n_pad"))
def _build_tree_leaves(
    x: jax.Array, key: jax.Array, depth: int, n_pad: int
) -> jax.Array:
    """One tree: [2^depth, leaf_size] point indices (pads hold idx >= N).

    Level ``l`` sorts each of the 2^l equal-length segments by the points'
    projection onto that level's random direction; halving sorted segments
    is exactly a median split, so the tree stays perfectly balanced.  Pads
    project to +inf and sink to the high side of every split.
    """
    n, d = x.shape
    dirs = jax.random.normal(key, (depth, d), x.dtype) if depth else None
    proj = x @ dirs.T if depth else None             # [N, depth]
    order = jnp.arange(n_pad, dtype=jnp.int32)
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    pos = jnp.arange(n_pad, dtype=jnp.int32)
    for level in range(depth):
        seg = pos // (n_pad >> level)
        p = jnp.where(order < n, proj[jnp.clip(order, 0, n - 1), level], big)
        _, _, order = lax.sort((seg, p, order), num_keys=2)
    return order.reshape(1 << depth, n_pad >> depth)


@functools.partial(jax.jit, static_argnames=("k", "n_pad"))
def _leaf_topk(x: jax.Array, leaves: jax.Array, k: int, n_pad: int):
    """Exact top-k within each leaf's candidate set, scattered per point.

    Returns ``(idx [n_pad, kk], d2 [n_pad, kk])`` with ``kk = min(k, S-1)``;
    rows >= N are pad slots the caller slices off.
    """
    n = x.shape[0]
    n_leaves, s = leaves.shape
    kk = min(k, s - 1)
    safe = jnp.clip(leaves, 0, n - 1)
    xb = x[safe]                                     # [L, S, D]
    sqn = jnp.sum(xb * xb, axis=2)
    d2 = sqn[:, :, None] + sqn[:, None, :] - 2.0 * jnp.einsum(
        "lsd,ltd->lst", xb, xb
    )
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)
    pad_col = (leaves >= n)[:, None, :]
    self_col = jnp.eye(s, dtype=bool)[None]
    d2 = jnp.where(pad_col | self_col, big, d2)
    neg_top, argtop = lax.top_k(-d2, kk)             # [L, S, kk]
    glob = jnp.take_along_axis(
        jnp.broadcast_to(leaves[:, None, :], (n_leaves, s, s)), argtop, axis=2
    )
    out_i = jnp.zeros((n_pad, kk), jnp.int32).at[leaves.reshape(-1)].set(
        glob.reshape(-1, kk)
    )
    out_d = jnp.zeros((n_pad, kk), x.dtype).at[leaves.reshape(-1)].set(
        jnp.maximum(-neg_top, 0.0).reshape(-1, kk)
    )
    return out_i, out_d


@functools.partial(
    jax.jit, static_argnames=("k", "n_trees", "depth", "block_rows")
)
def rp_forest_knn(
    x: jax.Array,
    k: int,
    *,
    n_trees: int = 8,
    depth: int = 4,
    seed: int = 0,
    block_rows: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Approximate KNN via ``n_trees`` RP trees of ``depth`` median splits."""
    n = x.shape[0]
    leaf = -(-n // (1 << depth))                     # ceil(n / 2^depth)
    n_pad = leaf << depth
    key = jax.random.PRNGKey(seed)
    best_i, best_d = seed_graph(x, k, jax.random.fold_in(key, n_trees),
                                block_rows=block_rows)
    # collect every tree's within-leaf top-k, then fold once: a single wide
    # dedup/top-k merge beats n_trees narrow ones (the sort dominates)
    cand_i, cand_d = [], []
    for t in range(n_trees):
        leaves = _build_tree_leaves(x, jax.random.fold_in(key, t), depth, n_pad)
        ti, td = _leaf_topk(x, leaves, k, n_pad)
        cand_i.append(ti[:n])
        cand_d.append(td[:n])
    return merge_topk(
        best_i, best_d,
        jnp.concatenate(cand_i, axis=1), jnp.concatenate(cand_d, axis=1),
        k, n,
    )


@dataclasses.dataclass(frozen=True)
class RPForestNeighbors:
    """Forest of random-projection trees; ``refine_iters`` adds NN-descent
    polish passes over the forest graph (see ``nn_descent.py``)."""

    name: ClassVar[str] = "rp_forest"
    n_trees: int = 8
    leaf_size: int = 64
    refine_iters: int = 2
    seed: int = 0
    block_rows: int = 512

    def resolve_depth(self, n: int, k: int) -> int:
        """Deepest split keeping leaves >= max(leaf_size, k+1) points, so a
        single leaf can supply a full top-k row."""
        leaf = max(self.leaf_size, k + 1)
        return max(0, int(math.floor(math.log2(max(1.0, n / leaf)))))

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_k(x.shape[0], k)
        idx, d2 = rp_forest_knn(
            x, k,
            n_trees=self.n_trees,
            depth=self.resolve_depth(x.shape[0], k),
            seed=self.seed,
            block_rows=self.block_rows,
        )
        if self.refine_iters > 0:
            from repro.neighbors.nn_descent import nn_descent_knn
            # offset the seed: refine rounds must not replay the PRNG keys
            # that drew the tree hyperplanes (fold_in shares the int domain)
            idx, d2 = nn_descent_knn(
                x, k, init=(idx, d2), n_iters=self.refine_iters,
                seed=self.seed + 1, block_rows=self.block_rows,
            )
        return idx, d2


register_neighbor_backend("rp_forest", RPForestNeighbors)
