"""Pluggable K-nearest-neighbor backends (paper §3.1, beyond exact).

    from repro.neighbors import make_neighbor_backend
    idx, d2 = make_neighbor_backend("rp_forest").neighbors(x, k)

Backends ("exact" | "rp_forest" | "nn_descent" | "sharded", or your own
via :func:`register_neighbor_backend`) plug in behind ``preprocess`` /
``TSNE(neighbor_method=...)`` exactly like gradient backends do behind
``method=``.  "sharded" distributes the build over a 1-D device mesh
(per-shard rp_forest + candidate ring — the million-point path).
"""
from repro.neighbors.base import (
    NeighborBackend, NeighborIndex, available_neighbor_backends,
    build_query_index, make_neighbor_backend, recall_at_k,
    register_neighbor_backend, unregister_neighbor_backend, validate_k,
    validate_query_k,
)
from repro.neighbors.exact import ExactIndex, ExactNeighbors
from repro.neighbors.rp_forest import (
    RPForestIndex, RPForestNeighbors, forest_query, rp_forest_knn,
)
from repro.neighbors.nn_descent import NNDescentNeighbors, nn_descent_knn
from repro.neighbors.sharded import ShardedNeighbors
from repro.neighbors._candidates import merge_topk, seed_graph

__all__ = [
    "NeighborBackend", "NeighborIndex",
    "ExactNeighbors", "RPForestNeighbors", "NNDescentNeighbors",
    "ShardedNeighbors",
    "ExactIndex", "RPForestIndex",
    "register_neighbor_backend", "unregister_neighbor_backend",
    "available_neighbor_backends", "make_neighbor_backend", "validate_k",
    "validate_query_k", "build_query_index",
    "recall_at_k", "rp_forest_knn", "nn_descent_knn", "forest_query",
    "merge_topk", "seed_graph",
]
