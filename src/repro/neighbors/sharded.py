"""Sharded neighbor backend: the distributed KNN rings behind the registry.

Before this backend existed the distributed path (``core/distributed.py``)
predated the ``NeighborBackend`` registry and always rang *exact*
brute-force KNN — O(N²/S · D) per shard, the reason nothing had run past
50k points.  ``ShardedNeighbors`` puts both rings behind the standard
``neighbors(x, k)`` contract:

* ``mode="approx"`` (default) — per-shard rp_forest + candidate ring
  (:func:`repro.core.distributed.ring_knn_approx`): each shard routes the
  visiting query block down its resident forest and merges leaf candidates
  into the traveling global top-k.  Peak memory is bounded by
  ``block_rows``, not N.
* ``mode="exact"`` — the original exact ring
  (:func:`repro.core.distributed.ring_knn`), kept as the recall oracle.

``shards=None`` uses every visible JAX device (1 on a plain CPU process —
the ring degenerates to a single local forest pass, still row-blocked, so
the memory bound holds on one device too).  Force S host devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=S`` before importing
jax.  Inputs of any N are handled by zero-padding to a shard multiple;
pad rows are masked out of every merge.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.neighbors.base import register_neighbor_backend, validate_k

MODES = ("approx", "exact")


@dataclasses.dataclass(frozen=True)
class ShardedNeighbors:
    """Distributed KNN over a 1-D device mesh (see module docstring).

    shards     : device count (None = all visible devices, clamped so each
                 shard keeps > k points)
    mode       : "approx" (rp_forest candidate ring) | "exact" (oracle ring)
    n_trees    : forest width per shard (approx mode)
    leaf_size  : leaf occupancy floor per tree (approx mode); the candidate
                 set per hop is n_trees * max(leaf_size, k+1)-ish columns
    block_rows : rows per routing/scoring/merge slice — the memory knob
    """

    name: ClassVar[str] = "sharded"
    shards: int | None = None
    mode: str = "approx"
    n_trees: int = 8
    leaf_size: int = 64
    block_rows: int = 4096
    seed: int = 0
    axis: str = "knn"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"unknown sharded mode {self.mode!r} (known: {', '.join(MODES)})"
            )

    def resolve_shards(self, n: int, k: int) -> int:
        """Devices actually used: requested (or all), bounded by what keeps
        every shard larger than k+1 points."""
        avail = len(jax.devices())
        s = avail if self.shards in (None, 0) else int(self.shards)
        if s > avail:
            raise ValueError(
                f"shards={s} but only {avail} JAX device(s) are visible — "
                "set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{s} before importing jax, or lower shards"
            )
        return max(1, min(s, n // (k + 2)))

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        from repro.core.distributed import ring_knn, ring_knn_approx

        n = int(x.shape[0])
        validate_k(n, k)
        s = self.resolve_shards(n, k)
        mesh = Mesh(np.asarray(jax.devices()[:s]), (self.axis,))
        pad = (-n) % s
        xp = jnp.pad(jnp.asarray(x), ((0, pad), (0, 0)))
        if self.mode == "exact":
            idx, d2 = ring_knn(mesh, xp, k, self.axis, n_valid=n)
        else:
            idx, d2 = ring_knn_approx(
                mesh, xp, k, self.axis, n_valid=n,
                n_trees=self.n_trees, leaf_size=self.leaf_size,
                block_rows=self.block_rows, seed=self.seed,
            )
        return idx[:n], d2[:n]


register_neighbor_backend("sharded", ShardedNeighbors)
