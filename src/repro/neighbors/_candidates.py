"""Shared candidate-set machinery for the approximate neighbor backends.

Both ``rp_forest`` and ``nn_descent`` reduce to the same inner loop: gather
a fixed-width candidate set per point, score it with exact squared
distances, and fold it into a running top-k while dropping duplicate /
invalid columns.  Everything here is shape-static and jittable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def _big(dtype) -> jax.Array:
    return jnp.asarray(jnp.finfo(dtype).max, dtype)


def merge_topk(
    best_i: jax.Array,
    best_d: jax.Array,
    cand_i: jax.Array,
    cand_d: jax.Array,
    k: int,
    n: int,
    exclude_self: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Fold candidate columns into a running top-k, row by row.

    ``best_* [N, K0]`` and ``cand_* [N, C]`` are row-aligned; candidates with
    index outside ``[0, n)`` or equal to the row index are dropped, and
    duplicate indices keep a single copy.  Returns ``(idx [N, k], d2 [N, k])``
    sorted ascending by distance.

    ``exclude_self=False`` skips the row-index drop — the query path, where
    rows are *new* points and candidate index ``i`` in row ``i`` is a
    coincidence, not a self-edge.
    """
    ci = jnp.concatenate([best_i, cand_i], axis=1).astype(jnp.int32)
    cd = jnp.concatenate([best_d, cand_d], axis=1)
    big = _big(cd.dtype)
    invalid = (ci < 0) | (ci >= n)
    if exclude_self:
        rows = jnp.arange(ci.shape[0], dtype=jnp.int32)[:, None]
        invalid = invalid | (ci == rows)
    cd = jnp.where(invalid, big, cd)
    # sort columns by index so duplicates become adjacent, then mask repeats
    order = jnp.argsort(ci, axis=1)
    ci = jnp.take_along_axis(ci, order, axis=1)
    cd = jnp.take_along_axis(cd, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ci[:, :1], bool), ci[:, 1:] == ci[:, :-1]], axis=1
    )
    cd = jnp.where(dup, big, cd)
    neg_top, argtop = lax.top_k(-cd, k)
    return jnp.take_along_axis(ci, argtop, axis=1), -neg_top


def candidate_sq_dists(
    x: jax.Array, cand: jax.Array, block_rows: int = 512,
    q: jax.Array | None = None,
) -> jax.Array:
    """``d2[i, j] = ||row_i - x[cand[i, j]]||²``, computed in row blocks.

    Rows come from ``q`` when given (out-of-sample queries scored against the
    reference set ``x``), else from ``x`` itself (the self-KNN build path).
    ``cand`` entries are clipped to ``[0, n)`` for the gather; callers mask
    out-of-range columns themselves (merge_topk does).  Row blocking bounds
    the ``[B, C, D]`` gather transient instead of materializing ``[N, C, D]``.
    """
    n, _ = x.shape
    rows = x if q is None else q
    m = rows.shape[0]
    sqn = jnp.sum(x * x, axis=1)
    cand = jnp.clip(cand, 0, n - 1).astype(jnp.int32)

    pad = (-m) % block_rows
    xp = jnp.pad(rows, ((0, pad), (0, 0)))
    candp = jnp.pad(cand, ((0, pad), (0, 0)))
    n_blocks = xp.shape[0] // block_rows

    def one_block(b):
        xb = lax.dynamic_slice_in_dim(xp, b * block_rows, block_rows)
        cb = lax.dynamic_slice_in_dim(candp, b * block_rows, block_rows)
        xc = x[cb]                                   # [B, C, D]
        dots = jnp.einsum("bd,bcd->bc", xb, xc)
        d2 = jnp.sum(xb * xb, axis=1)[:, None] + sqn[cb] - 2.0 * dots
        return jnp.maximum(d2, 0.0)

    d2 = lax.map(one_block, jnp.arange(n_blocks))
    return d2.reshape(-1, cand.shape[1])[:m]


@functools.partial(jax.jit, static_argnames=("k", "block_rows"))
def seed_graph(
    x: jax.Array, k: int, key: jax.Array, block_rows: int = 512
) -> tuple[jax.Array, jax.Array]:
    """A valid (if poor) starting graph: k distinct non-self neighbors per row.

    Shared random offsets keep every slot a real point, so backends that
    merge into this state can never emit an invalid index even when their
    candidate generation comes up short.
    """
    n = x.shape[0]
    offsets = 1 + jax.random.choice(
        key, jnp.arange(n - 1, dtype=jnp.int32), (k,), replace=False
    )
    idx = (jnp.arange(n, dtype=jnp.int32)[:, None] + offsets[None, :]) % n
    return idx, candidate_sq_dists(x, idx, block_rows=block_rows)
