"""Exact neighbor backend — the blocked brute force from ``core/knn.py``.

O(N²·D), but every distance is evaluated on the MXU (Pallas or XLA pairwise
tiles), so it is the right default up to ~50k points and the recall oracle
for the approximate backends at any size.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax

from repro.core.knn import knn
from repro.neighbors.base import register_neighbor_backend, validate_k


@dataclasses.dataclass(frozen=True)
class ExactNeighbors:
    """Blocked brute-force KNN (paper §3.1 — recall 1.0 by construction)."""

    name: ClassVar[str] = "exact"
    block_q: int = 512
    block_db: int = 2048
    pairwise: str = "xla"          # "xla" | "pallas" distance-tile kernel

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_k(x.shape[0], k)
        return knn(
            x, k,
            block_q=self.block_q, block_db=self.block_db,
            pairwise_fn_name=self.pairwise,
        )


register_neighbor_backend("exact", ExactNeighbors)
