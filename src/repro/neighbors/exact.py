"""Exact neighbor backend — the blocked brute force from ``core/knn.py``.

O(N²·D), but every distance is evaluated on the MXU (Pallas or XLA pairwise
tiles), so it is the right default up to ~50k points and the recall oracle
for the approximate backends at any size.  The query index is the same
blocked scan with query rows swapped in for the database rows — recall 1.0
for out-of-sample points too.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.knn import knn, knn_query
from repro.neighbors.base import (
    register_neighbor_backend, validate_k, validate_query_k,
)


@dataclasses.dataclass(frozen=True, eq=False)
class ExactIndex:
    """Brute-force query index: holds the reference points verbatim."""

    x_ref: jax.Array
    block_q: int = 512
    block_db: int = 2048
    pairwise: str = "xla"

    @property
    def n_reference(self) -> int:
        return int(self.x_ref.shape[0])

    def query(self, x_new: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_query_k(self.n_reference, k)
        return knn_query(
            x_new.astype(self.x_ref.dtype), self.x_ref, k,
            block_q=self.block_q, block_db=self.block_db,
            pairwise_fn_name=self.pairwise,
        )


@dataclasses.dataclass(frozen=True)
class ExactNeighbors:
    """Blocked brute-force KNN (paper §3.1 — recall 1.0 by construction)."""

    name: ClassVar[str] = "exact"
    block_q: int = 512
    block_db: int = 2048
    pairwise: str = "xla"          # "xla" | "pallas" distance-tile kernel

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_k(x.shape[0], k)
        return knn(
            x, k,
            block_q=self.block_q, block_db=self.block_db,
            pairwise_fn_name=self.pairwise,
        )

    def build_index(self, x: jax.Array) -> ExactIndex:
        return ExactIndex(
            x_ref=jnp.asarray(x),
            block_q=self.block_q, block_db=self.block_db,
            pairwise=self.pairwise,
        )


register_neighbor_backend("exact", ExactNeighbors)
