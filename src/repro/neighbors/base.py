"""Neighbor-backend protocol + string-keyed registry (mirrors api/backends.py).

A *neighbor backend* owns step 1 of the pipeline (paper §3.1): given the
input points it returns the K-nearest-neighbor graph ``(idx [N, K] int32,
d2 [N, K])`` that the perplexity search and symmetrization consume.
Backends are frozen dataclasses — hashable and cheap to construct — so they
can ride through jitted drivers the same way gradient backends do.

Three first-class implementations ship with the repo:

* ``exact``      — blocked brute force (``core/knn.py``), O(N²·D); the
                   recall oracle and the right choice up to ~50k points
* ``rp_forest``  — random-projection tree forest: batched median
                   hyperplane splits to fixed-depth leaves, exact top-k
                   within each leaf, merged across trees
* ``nn_descent`` — iterative neighbor-of-neighbor refinement over a
                   fixed-width candidate graph; standalone or as a polish
                   pass over the forest output

Register your own with :func:`register_neighbor_backend`; the estimator's
``neighbor_method=`` and ``TsneConfig.neighbor_method`` both dispatch
through :func:`make_neighbor_backend`.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping, Protocol, runtime_checkable

import jax
import numpy as np


@runtime_checkable
class NeighborBackend(Protocol):
    """What ``preprocess`` needs from a neighbor backend.

    ``neighbors(x, k)`` maps points ``x [N, D]`` to ``(idx [N, k] int32,
    d2 [N, k])`` — each row lists k distinct neighbors of the row point
    (self excluded) with their squared euclidean distances.  Approximate
    backends may return non-optimal neighbors, never invalid indices.

    Backends that support out-of-sample queries additionally implement
    ``build_index(x) -> NeighborIndex`` (see :func:`build_query_index` for
    the registry-level entry point with an exact fallback).
    """

    name: str

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        ...


@runtime_checkable
class NeighborIndex(Protocol):
    """A fitted reference set that answers out-of-sample KNN queries.

    ``query(x_new, k)`` maps query points ``x_new [M, D]`` (NOT members of
    the reference set) to ``(idx [M, k] int32, d2 [M, k])`` — reference-set
    indices of the k nearest fitted points per query, ascending by distance,
    with exact squared distances for the selected candidates.  There is no
    self-exclusion: the true nearest reference point is always a valid
    answer.  ``n_reference`` is the fitted set size.
    """

    n_reference: int

    def query(self, x_new: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        ...


def build_query_index(backend: NeighborBackend, x: jax.Array) -> NeighborIndex:
    """Fit ``backend``'s query index over reference points ``x``.

    Backends without a ``build_index`` method (e.g. custom registrations, or
    ``nn_descent`` whose neighbor-of-neighbor refinement has no meaningful
    frozen query structure) fall back to the exact blocked brute force —
    always correct, O(M·N·D) per query batch.
    """
    builder = getattr(backend, "build_index", None)
    if builder is not None:
        return builder(x)
    from repro.neighbors.exact import ExactNeighbors  # lazy: exact builds on base
    return ExactNeighbors().build_index(x)


def validate_query_k(n_reference: int, k: int) -> None:
    """Query (n, k) precondition: 1 <= k <= reference-set size."""
    if k < 1:
        raise ValueError(f"k={k} must be >= 1")
    if k > n_reference:
        raise ValueError(
            f"k={k} must be <= reference-set size n={n_reference}"
        )


def recall_at_k(ref_idx, idx) -> float:
    """Mean fraction of the reference k-neighbors recovered (host-side).

    Requires each row of both arrays to hold distinct indices (every backend
    here guarantees that), so per row
    ``|ref ∩ approx| = 2k - #unique(ref ++ approx)``.
    """
    ref_idx = np.asarray(ref_idx)
    idx = np.asarray(idx)
    both = np.sort(np.concatenate([ref_idx, idx], axis=1), axis=1)
    n_dup = (both[:, 1:] == both[:, :-1]).sum(axis=1)
    return float(n_dup.mean() / ref_idx.shape[1])


def validate_k(n: int, k: int) -> None:
    """Shared (n, k) precondition: at least one non-self neighbor per row."""
    if k < 1:
        raise ValueError(f"k={k} must be >= 1")
    if k >= n:
        raise ValueError(f"k={k} must be < n={n}")


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

# factory(**options) -> NeighborBackend; dataclass constructors qualify
NeighborFactory = Callable[..., NeighborBackend]

_REGISTRY: dict[str, NeighborFactory] = {}


def register_neighbor_backend(name: str, factory: NeighborFactory | None = None):
    """Register a neighbor-backend factory under ``name``.

    Usable directly — ``register_neighbor_backend("mine", MyNeighbors)`` —
    or as a decorator::

        @register_neighbor_backend("mine")
        def make_mine(**options) -> NeighborBackend:
            return MyNeighbors(**options)
    """
    def _register(fn: NeighborFactory) -> NeighborFactory:
        _REGISTRY[name] = fn
        return fn

    return _register(factory) if factory is not None else _register


def unregister_neighbor_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def available_neighbor_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_neighbor_backend(
    method: str, options: Mapping[str, Any] | None = None
) -> NeighborBackend:
    """Instantiate the backend registered under ``method`` with ``options``."""
    try:
        factory = _REGISTRY[method]
    except KeyError:
        raise ValueError(
            f"unknown neighbor method {method!r}; registered backends: "
            f"{', '.join(available_neighbor_backends())}"
        ) from None
    return factory(**dict(options or {}))
