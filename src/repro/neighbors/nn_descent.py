"""NN-descent: iterative neighbor-of-neighbor graph refinement (Dong et al.).

The classic observation — "a neighbor of my neighbor is probably my
neighbor" — as a fixed-width, shape-static JAX loop: every iteration
samples ``n_sample`` columns of the current graph, expands them one hop
forward (``idx[idx]``), scatters a bounded sample of *reverse* edges, scores
all candidates exactly, and folds them into the running top-k with
``lax.top_k`` merges.  Usable standalone from a random seed graph or as a
polish pass over ``rp_forest`` output (``init=``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.neighbors._candidates import candidate_sq_dists, merge_topk, seed_graph
from repro.neighbors.base import register_neighbor_backend, validate_k


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_iters", "n_sample", "n_reverse", "block_rows"),
)
def nn_descent_knn(
    x: jax.Array,
    k: int,
    *,
    init: tuple[jax.Array, jax.Array] | None = None,
    n_iters: int = 10,
    n_sample: int = 12,
    n_reverse: int = 12,
    seed: int = 0,
    block_rows: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Refine a KNN graph for ``n_iters`` rounds; ``init=None`` starts random.

    Candidate width per round is ``n_sample² + n_reverse``, so cost is
    O(N · n_iters · n_sample² · D) regardless of k.
    """
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    if init is None:
        idx, d2 = seed_graph(x, k, jax.random.fold_in(key, n_iters),
                             block_rows=block_rows)
    else:
        idx, d2 = init
    s = min(n_sample, k)
    rows = jnp.arange(n, dtype=jnp.int32)

    def one_round(it, carry):
        idx, d2 = carry
        kit = jax.random.fold_in(key, it)
        k1, k2, k3 = jax.random.split(kit, 3)
        samp = jnp.take_along_axis(
            idx, jax.random.randint(k1, (n, s), 0, k), axis=1
        )                                             # [n, s] sampled neighbors
        hop2 = jax.random.randint(k2, (n, s), 0, k)
        fwd = idx[samp[:, :, None], hop2[:, None, :]].reshape(n, s * s)
        # bounded reverse-edge sample: each sampled edge i -> samp[i, j]
        # nominates i as a candidate of samp[i, j]; hash collisions just drop
        slots = jax.random.randint(k3, (n, s), 0, n_reverse)
        rev = jnp.full((n, n_reverse), -1, jnp.int32).at[samp, slots].set(
            jnp.broadcast_to(rows[:, None], (n, s))
        )
        cand = jnp.concatenate([fwd, rev], axis=1)
        cd = candidate_sq_dists(x, cand, block_rows=block_rows)
        return merge_topk(idx, d2, cand, cd, k, n)

    idx, d2 = jax.lax.fori_loop(0, n_iters, one_round, (idx, d2))
    return idx, d2


@dataclasses.dataclass(frozen=True)
class NNDescentNeighbors:
    """Fixed-width NN-descent from a random seed graph."""

    name: ClassVar[str] = "nn_descent"
    n_iters: int = 10
    n_sample: int = 12
    n_reverse: int = 12
    seed: int = 0
    block_rows: int = 512

    def neighbors(self, x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
        validate_k(x.shape[0], k)
        return nn_descent_knn(
            x, k,
            n_iters=self.n_iters, n_sample=self.n_sample,
            n_reverse=self.n_reverse, seed=self.seed,
            block_rows=self.block_rows,
        )

    def build_index(self, x: jax.Array):
        """Out-of-sample queries fall back to the exact blocked scan: the
        neighbor-of-neighbor refinement leaves no frozen routing structure
        a new point could descend (unlike the forest's hyperplanes)."""
        from repro.neighbors.exact import ExactNeighbors
        return ExactNeighbors(block_db=self.block_rows * 4).build_index(x)


register_neighbor_backend("nn_descent", NNDescentNeighbors)
