"""Layer stacks: scanned homogeneous blocks + heterogeneous assemblies.

All stacks scan over layers (stacked [L, ...] param leaves) with optional
remat — compile time stays O(1) in depth, which is what makes the 126-layer
405B dry-run tractable, and is the production idiom (MaxText-style).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical
from repro.models.attention import attention_block, init_attention, init_kv_cache
from repro.models.layers import init_dense, rms_norm, swiglu
from repro.models.mla import init_mla, init_mla_cache, mla_block
from repro.models.moe import init_moe, moe_block
from repro.models.rwkv import init_rwkv, init_rwkv_cache, rwkv_block
from repro.models.ssm import init_mamba, init_ssm_cache, mamba_block


# ---------------------------------------------------------------------------
# single block (attention/mla + mlp/moe), used by dense/moe/enc-dec stacks
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, dtype, *, kind: str, d_ff: int | None = None):
    """kind: dense | moe | encoder | decoder_cross"""
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": jnp.zeros((d,), dtype)}
    if cfg.attention == "mla":
        p["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = init_attention(ks[0], cfg, dtype)
    if kind == "decoder_cross":
        p["ln_cross"] = jnp.zeros((d,), dtype)
        p["cross"] = init_attention(ks[1], cfg, dtype)
    p["ln2"] = jnp.zeros((d,), dtype)
    if kind == "moe":
        p["mlp"] = init_moe(ks[2], cfg, dtype)
    else:
        ff = d_ff or cfg.d_ff
        p["mlp"] = {
            "w1": init_dense(ks[2], (d, ff), dtype),
            "w3": init_dense(ks[3], (d, ff), dtype),
            "w2": init_dense(ks[4], (ff, d), dtype, scale=ff**-0.5 / (2 * cfg.n_layers) ** 0.5),
        }
    return p


def block_forward(params, x, positions, cfg: ArchConfig, *, kind: str,
                  cache=None, cache_pos=None, cross_kv=None, causal=True, use_rope=True):
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        attn_out, new_cache = mla_block(params["attn"], h, positions, cfg,
                                        cache=cache, cache_pos=cache_pos)
    else:
        attn_out, new_cache = attention_block(params["attn"], h, positions, cfg,
                                              causal=causal, use_rope=use_rope,
                                              cache=cache, cache_pos=cache_pos)
    x = x + attn_out
    if kind == "decoder_cross":
        h = rms_norm(x, params["ln_cross"], cfg.norm_eps)
        c_out, _ = attention_block(params["cross"], h, positions, cfg, cross_kv=cross_kv)
        x = x + c_out
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    aux = None
    if kind == "moe":
        mlp_out, aux = moe_block(params["mlp"], h, cfg)
    else:
        mlp_out = swiglu(h, **params["mlp"])
    x = x + mlp_out
    x = logical(x, "batch", "seq", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# scanned homogeneous stack
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ArchConfig, n_layers: int, dtype, *, kind: str, d_ff=None):
    keys = jax.random.split(key, n_layers)
    if cfg.scan_layers:
        return jax.vmap(lambda k: init_block(k, cfg, dtype, kind=kind, d_ff=d_ff))(keys)
    return [init_block(k, cfg, dtype, kind=kind, d_ff=d_ff) for k in keys]


def stack_forward(params, x, positions, cfg: ArchConfig, *, kind: str, n_layers: int,
                  cache=None, cache_pos=None, cross_kv=None, causal=True, use_rope=True):
    """Scan over a stacked [L, ...] block-param pytree. Returns (x, cache, aux)."""

    def body(carry, xs):
        xc, aux_acc = carry
        layer_params, layer_cache, layer_cross = xs
        xc, new_cache, aux = block_forward(
            layer_params, xc, positions, cfg, kind=kind, cache=layer_cache,
            cache_pos=cache_pos, cross_kv=layer_cross, causal=causal, use_rope=use_rope)
        if aux is not None:
            aux_acc = aux_acc + aux["aux_loss"]
        return (xc, aux_acc), new_cache

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers:
        (x, aux_sum), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                                (params, cache, cross_kv))
    else:
        aux_sum = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(n_layers):
            (x, aux_sum), nc = body(
                (x, aux_sum),
                (params[i], None if cache is None else cache[i],
                 None if cross_kv is None else jax.tree.map(lambda c: c[i], cross_kv)))
            new_caches.append(nc)
    return x, new_caches, aux_sum


# ---------------------------------------------------------------------------
# hybrid (zamba2-like): scanned Mamba2 layers + one shared attention block
# ---------------------------------------------------------------------------

def init_hybrid(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"mamba": jax.vmap(lambda k: init_mamba(k, cfg, dtype))(jax.random.split(k1, cfg.n_layers))}
    if cfg.ssm.shared_stride:
        p["shared"] = init_block(k2, cfg, dtype, kind="dense", d_ff=cfg.ssm.shared_d_ff)
    return p


def n_shared_apps(cfg: ArchConfig) -> int:
    s = cfg.ssm.shared_stride
    return 0 if not s else (cfg.n_layers + s - 1) // s


def hybrid_forward(params, x, positions, cfg: ArchConfig, *, cache=None, cache_pos=None):
    """cache = {'ssm': stacked [L,...], 'shared': stacked [n_apps,...]} or None."""
    stride = cfg.ssm.shared_stride
    apps = n_shared_apps(cfg)
    decode = cache is not None

    def body(carry, xs):
        xc, shared_cache = carry
        layer_params, layer_cache, idx = xs
        xc, new_ssm_cache = mamba_block(layer_params, xc, cfg, cache=layer_cache)

        if stride:
            def with_shared(args):
                xc, shared_cache = args
                app = idx // stride
                if decode:
                    this = jax.tree.map(lambda c: c[app], shared_cache)
                    out, new_c, _ = block_forward(params["shared"], xc, positions, cfg,
                                                  kind="dense", cache=this, cache_pos=cache_pos)
                    shared_cache = jax.tree.map(
                        lambda full, n: jax.lax.dynamic_update_index_in_dim(full, n, app, 0),
                        shared_cache, new_c)
                else:
                    out, _, _ = block_forward(params["shared"], xc, positions, cfg, kind="dense")
                return out, shared_cache

            apply = (idx % stride) == 0
            xc, shared_cache = jax.lax.cond(apply, with_shared, lambda a: a, (xc, shared_cache))
        return (xc, shared_cache), new_ssm_cache

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    shared_cache = cache["shared"] if decode and stride else ()
    ssm_cache = cache["ssm"] if decode else None
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    (x, shared_cache), new_ssm = jax.lax.scan(
        body, (x, shared_cache), (params["mamba"], ssm_cache, idxs))
    new_cache = {"ssm": new_ssm, "shared": shared_cache} if decode else None
    return x, new_cache


def init_hybrid_cache(cfg: ArchConfig, batch: int, seq: int, dtype):
    apps = n_shared_apps(cfg)
    ssm = jax.vmap(lambda _: init_ssm_cache(cfg, batch, dtype))(jnp.arange(cfg.n_layers))
    out = {"ssm": ssm, "shared": ()}
    if apps:
        out["shared"] = jax.vmap(lambda _: init_kv_cache(cfg, batch, seq, dtype))(jnp.arange(apps))
    return out
