"""Shared model building blocks: norms, RoPE, SwiGLU, chunked attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical


def init_dense(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def swiglu(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    h = logical(h, "batch", None, "ffn")   # seq left free: it may be SP-sharded
    return h @ w2


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, dh] (dh even), positions: [..., S] int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def attention(q, k, v, *, causal: bool, q_offset=0, mask=None):
    """Plain softmax attention — reference path and decode path.

    q: [B, Sq, H, dh], k/v: [B, Skv, Hkv, dh].  f32 softmax accumulation.
    """
    h, hkv = q.shape[2], k.shape[2]
    k = _repeat_kv(k, h // hkv)
    v = _repeat_kv(v, h // hkv)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where((kpos <= qpos)[None, None], logits, -1e30)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 512, q_offset: int = 0):
    """Flash attention (online softmax over KV chunks) with a custom VJP.

    Forward never materializes the [Sq, Skv] score matrix; the custom
    backward recomputes per-chunk probabilities from the saved (out, lse)
    instead of differentiating through the scan — without this, autodiff
    saves the f32 accumulator per chunk iteration and a 32k-context layer
    costs O(n_chunks * B*H*S*dh) bytes (the 773 GiB/device failure mode).
    """
    key = (bool(causal), int(chunk), int(q_offset))
    if key not in _FLASH_CACHE:
        _FLASH_CACHE[key] = _make_flash(*key)
    return _FLASH_CACHE[key](q, k, v)


_FLASH_CACHE: dict = {}


def _pad_kv(k, chunk):
    skv = k.shape[1]
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, skv


def _fa_forward(q, k, v, causal, chunk, q_offset):
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    n_rep = h // hkv
    k, kv_valid = _pad_kv(k, chunk)
    v, _ = _pad_kv(v, chunk)
    n_chunks = k.shape[1] // chunk
    scale = dh**-0.5
    qf = q.astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset

    def body(carry, c):
        m, l, acc = carry
        kc = _repeat_kv(jax.lax.dynamic_slice_in_dim(k, c * chunk, chunk, 1), n_rep).astype(jnp.float32)
        vc = _repeat_kv(jax.lax.dynamic_slice_in_dim(v, c * chunk, chunk, 1), n_rep).astype(jnp.float32)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kc)
        kpos = c * chunk + jnp.arange(chunk)[None, :]
        valid = kpos < kv_valid
        if causal:
            valid = valid & (kpos <= qpos)
        logits = jnp.where(valid[None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(n_chunks))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))         # [B, H, Sq] f32
    return out, lse


def _make_flash(causal, chunk, q_offset):
    @jax.custom_vjp
    def fa(q, k, v):
        out, _ = _fa_forward(q, k, v, causal, chunk, q_offset)
        return out

    def fwd(q, k, v):
        out, lse = _fa_forward(q, k, v, causal, chunk, q_offset)
        return out, (q, k, v, out, lse)

    def bwd(res, dout):
        q, k, v, out, lse = res
        b, sq, h, dh = q.shape
        hkv = k.shape[2]
        n_rep = h // hkv
        kp, kv_valid = _pad_kv(k, chunk)
        vp, _ = _pad_kv(v, chunk)
        n_chunks = kp.shape[1] // chunk
        scale = dh**-0.5
        qf = q.astype(jnp.float32)
        doutf = dout.astype(jnp.float32)
        # delta = rowsum(dout * out) [B, H, Sq]
        delta = jnp.einsum("bqhd,bqhd->bhq", doutf, out.astype(jnp.float32))
        lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)
        qpos = jnp.arange(sq)[:, None] + q_offset

        def body(dq, c):
            kc = _repeat_kv(jax.lax.dynamic_slice_in_dim(kp, c * chunk, chunk, 1), n_rep).astype(jnp.float32)
            vc = _repeat_kv(jax.lax.dynamic_slice_in_dim(vp, c * chunk, chunk, 1), n_rep).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc) * scale
            kpos = c * chunk + jnp.arange(chunk)[None, :]
            valid = kpos < kv_valid
            if causal:
                valid = valid & (kpos <= qpos)
            p = jnp.where(valid[None, None], jnp.exp(s - lse_safe[..., None]), 0.0)
            dv_c = jnp.einsum("bhqk,bqhd->bkhd", p, doutf)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doutf, vc)
            ds = p * (dp - delta[..., None]) * scale
            dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, kc)
            dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
            # sum the GQA query-head group back onto the shared KV head
            dk_c = dk_c.reshape(b, chunk, hkv, n_rep, dh).sum(3)
            dv_c = dv_c.reshape(b, chunk, hkv, n_rep, dh).sum(3)
            return dq, (dk_c.astype(k.dtype), dv_c.astype(v.dtype))

        dq0 = jnp.zeros((b, sq, h, dh), jnp.float32)
        dq, (dk_chunks, dv_chunks) = jax.lax.scan(body, dq0, jnp.arange(n_chunks))
        dk = jnp.moveaxis(dk_chunks, 0, 1).reshape(b, n_chunks * chunk, hkv, dh)[:, : k.shape[1]]
        dv = jnp.moveaxis(dv_chunks, 0, 1).reshape(b, n_chunks * chunk, hkv, dh)[:, : v.shape[1]]
        return dq.astype(q.dtype), dk, dv

    fa.defvjp(fwd, bwd)
    return fa
