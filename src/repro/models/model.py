"""Top-level model API: build_model(cfg) -> init / loss / prefill / decode.

One code path serves all 10 assigned architectures:
  dense  : scanned GQA decoder (llama3-405b, minitron, deepseek-7b, phi4)
  moe    : leading dense layers + scanned MLA+MoE layers (+ optional MTP)
  ssm    : scanned RWKV6 blocks (attention-free)
  hybrid : scanned Mamba2 + shared attention block (zamba2)
  audio  : whisper-style enc-dec (stubbed conv frontend: frame embeddings in)
  vlm    : dense decoder over [patch embeddings ; text tokens] (anyres stub)
"""
from __future__ import annotations

import dataclasses
import functools
from types import SimpleNamespace
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import logical
from repro.models.attention import init_kv_cache
from repro.models.layers import init_dense, rms_norm
from repro.models.mla import init_mla_cache
from repro.models.rwkv import init_rwkv, init_rwkv_cache, rwkv_block
from repro.models.transformer import (
    block_forward, hybrid_forward, init_block, init_hybrid, init_hybrid_cache,
    init_stack, stack_forward,
)


def _sinusoid(positions, d, dtype):
    """[..., S] -> [..., S, d] sinusoidal embedding (whisper-style)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _cross_entropy(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    return jnp.sum(nll) / denom


# ---------------------------------------------------------------------------


def build_model(cfg: ArchConfig):
    dtype = cfg.cdtype()
    pdtype = cfg.pdtype()
    fam = cfg.family

    # ------------------------------------------------------------- init ----
    def init(key) -> dict:
        ks = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": {"embedding": init_dense(ks[0], (cfg.vocab_size, cfg.d_model), pdtype, scale=1.0)},
            "final_ln": jnp.zeros((cfg.d_model,), pdtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = init_dense(ks[1], (cfg.d_model, cfg.vocab_size), pdtype)
        if fam in ("dense", "vlm"):
            params["layers"] = init_stack(ks[2], cfg, cfg.n_layers, pdtype, kind="dense")
        elif fam == "moe":
            nd = cfg.moe.first_dense_layers
            if nd:
                params["dense_layers"] = init_stack(ks[2], cfg, nd, pdtype,
                                                    kind="dense", d_ff=cfg.moe.dense_d_ff)
            params["layers"] = init_stack(ks[3], cfg, cfg.n_layers - nd, pdtype, kind="moe")
            if cfg.mtp:
                params["mtp"] = {
                    "proj": init_dense(ks[4], (2 * cfg.d_model, cfg.d_model), pdtype),
                    "ln_h": jnp.zeros((cfg.d_model,), pdtype),
                    "ln_e": jnp.zeros((cfg.d_model,), pdtype),
                    "block": init_block(ks[5], cfg, pdtype, kind="moe"),
                    "final_ln": jnp.zeros((cfg.d_model,), pdtype),
                }
        elif fam == "ssm":
            params["layers"] = jax.vmap(lambda k: init_rwkv(k, cfg, pdtype))(
                jax.random.split(ks[2], cfg.n_layers))
        elif fam == "hybrid":
            params["layers"] = init_hybrid(ks[2], cfg, pdtype)
        elif fam == "audio":
            params["encoder"] = init_stack(ks[2], cfg, cfg.enc_dec.n_encoder_layers,
                                           pdtype, kind="dense")
            params["layers"] = init_stack(ks[3], cfg, cfg.n_layers, pdtype, kind="decoder_cross")
        else:
            raise ValueError(fam)
        return params

    # -------------------------------------------------------- backbones ----
    def _cast(params):
        """Cast float params to the compute dtype (storage stays param_dtype)."""
        return jax.tree.map(
            lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
            params)

    def _embed(params, tokens):
        x = params["embed"]["embedding"].astype(dtype)[tokens]
        return logical(x, "batch", "seq", "embed")

    def _head(params, x):
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        w = (params["embed"]["embedding"].T if cfg.tie_embeddings else params["unembed"]).astype(dtype)
        logits = x @ w
        return logical(logits, "batch", None, "vocab")

    def _encoder(params, frames):
        # stubbed frontend: frames are precomputed embeddings [B, F, D]
        f = frames.shape[1]
        x = frames.astype(dtype) + _sinusoid(jnp.arange(f), cfg.d_model, dtype)[None]
        x, _, _ = stack_forward(params["encoder"], x, jnp.arange(f), cfg,
                                kind="dense", n_layers=cfg.enc_dec.n_encoder_layers,
                                causal=False, use_rope=False)
        return x

    def _cross_kv(params, enc_out):
        """Per-decoder-layer cross K/V from encoder output (vmap over layers)."""
        b, f, _ = enc_out.shape
        hd = cfg.resolved_head_dim

        def one(layer_p):
            k = (enc_out @ layer_p["cross"]["wk"].astype(dtype)).reshape(b, f, cfg.n_kv_heads, hd)
            v = (enc_out @ layer_p["cross"]["wv"].astype(dtype)).reshape(b, f, cfg.n_kv_heads, hd)
            return k, v

        return jax.vmap(one)(params["layers"]) if cfg.scan_layers else None

    def _backbone(params, x, positions, *, cache=None, cache_pos=None, cross_kv=None):
        """Returns (hidden, new_cache, aux_loss_sum)."""
        aux = jnp.zeros((), jnp.float32)
        if fam in ("dense", "vlm"):
            x, new_cache, _ = stack_forward(params["layers"], x, positions, cfg,
                                            kind="dense", n_layers=cfg.n_layers,
                                            cache=cache, cache_pos=cache_pos)
            return x, new_cache, aux
        if fam == "moe":
            nd = cfg.moe.first_dense_layers
            dc = mc = None
            if cache is not None:
                dc, mc = cache.get("dense"), cache["moe"]
            new_dense = None
            if nd:
                x, new_dense, _ = stack_forward(params["dense_layers"], x, positions, cfg,
                                                kind="dense", n_layers=nd,
                                                cache=dc, cache_pos=cache_pos)
            x, new_moe, aux = stack_forward(params["layers"], x, positions, cfg,
                                            kind="moe", n_layers=cfg.n_layers - nd,
                                            cache=mc, cache_pos=cache_pos)
            new_cache = None
            if cache is not None:
                new_cache = {"dense": new_dense, "moe": new_moe}
            return x, new_cache, aux
        if fam == "ssm":
            def body(carry, xs):
                xc = carry
                layer_params, layer_cache = xs
                xc, nc = rwkv_block(layer_params, xc, cfg, cache=layer_cache)
                return xc, nc
            if cfg.remat:
                body = jax.checkpoint(body, prevent_cse=False)
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
            return x, (new_cache if cache is not None else None), aux
        if fam == "hybrid":
            x, new_cache = hybrid_forward(params["layers"], x, positions, cfg,
                                          cache=cache, cache_pos=cache_pos)
            return x, new_cache, aux
        if fam == "audio":
            x, new_cache, _ = stack_forward(params["layers"], x, positions, cfg,
                                            kind="decoder_cross", n_layers=cfg.n_layers,
                                            cache=cache, cache_pos=cache_pos, cross_kv=cross_kv)
            return x, new_cache, aux
        raise ValueError(fam)

    # ----------------------------------------------------------- losses ----
    def loss_fn(params, batch):
        params = _cast(params)
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        s = inputs.shape[1]
        x = _embed(params, inputs)
        mask = None
        cross_kv = None
        if fam == "vlm":
            patches = batch["patches"].astype(dtype)
            x = jnp.concatenate([patches, x], axis=1)
            labels = jnp.concatenate(
                [jnp.zeros(patches.shape[:2], labels.dtype), labels], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(patches.shape[:2], jnp.float32),
                 jnp.ones((inputs.shape[0], s), jnp.float32)], axis=1)
            s = x.shape[1]
        if fam == "audio":
            enc_out = _encoder(params, batch["frames"])
            cross_kv = _cross_kv(params, enc_out)
            x = x + _sinusoid(jnp.arange(s), cfg.d_model, dtype)[None]
        positions = jnp.arange(s)
        h, _, aux = _backbone(params, x, positions, cross_kv=cross_kv)
        logits = _head(params, h)
        loss = _cross_entropy(logits, labels, mask)
        metrics = {"lm_loss": loss}
        if fam == "moe":
            metrics["aux_loss"] = aux
            loss = loss + cfg.moe.aux_loss_weight * aux
            if cfg.mtp:
                mtp_loss = _mtp_loss(params, h, tokens)
                metrics["mtp_loss"] = mtp_loss
                loss = loss + cfg.mtp_loss_weight * mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(params, h, tokens):
        """DeepSeek-V3 MTP depth-1: predict t+2 from (h_t, emb_{t+1})."""
        p = params["mtp"]
        inputs, nxt, tgt = tokens[:, :-2], tokens[:, 1:-1], tokens[:, 2:]
        h = h[:, : inputs.shape[1]]
        e = _embed(params, nxt)
        z = jnp.concatenate([rms_norm(h, p["ln_h"], cfg.norm_eps),
                             rms_norm(e, p["ln_e"], cfg.norm_eps)], axis=-1) @ p["proj"].astype(dtype)
        z, _, _ = block_forward(p["block"], z, jnp.arange(z.shape[1]), cfg, kind="moe")
        logits = _head(params, z)
        return _cross_entropy(logits, tgt)

    # ------------------------------------------------------------ serve ----
    def prefill(params, batch):
        """Full-context forward; returns last-token logits."""
        params = _cast(params)
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = _embed(params, tokens)
        cross_kv = None
        if fam == "vlm":
            x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
            s = x.shape[1]
        if fam == "audio":
            enc_out = _encoder(params, batch["frames"])
            cross_kv = _cross_kv(params, enc_out)
            x = x + _sinusoid(jnp.arange(s), cfg.d_model, dtype)[None]
        positions = jnp.arange(s)
        h, _, _ = _backbone(params, x, positions, cross_kv=cross_kv)
        return _head(params, h[:, -1:, :])[:, 0]

    def decode_step(params, cache, token, pos):
        """One token with a filled KV/state cache. token [B], pos [B]."""
        params = _cast(params)
        x = _embed(params, token[:, None])
        cross_kv = None
        if fam == "audio":
            cross_kv = cache["cross"]
            x = x + _sinusoid(pos[:, None], cfg.d_model, dtype)
            h, new_self, _ = _backbone(params, x, pos[:, None], cache=cache["self"],
                                       cache_pos=pos, cross_kv=cross_kv)
            new_cache = {"self": new_self, "cross": cache["cross"]}
        else:
            h, new_cache, _ = _backbone(params, x, pos[:, None], cache=cache, cache_pos=pos)
        logits = _head(params, h)[:, 0]
        return logits, new_cache

    # ------------------------------------------------------------ cache ----
    def init_cache(batch: int, seq: int):
        cdt = dtype
        if fam in ("dense", "vlm"):
            if cfg.attention == "mla":
                one = lambda _: init_mla_cache(cfg, batch, seq, cdt)
            else:
                one = lambda _: init_kv_cache(cfg, batch, seq, cdt)
            return jax.vmap(one)(jnp.arange(cfg.n_layers))
        if fam == "moe":
            nd = cfg.moe.first_dense_layers
            one = lambda _: init_mla_cache(cfg, batch, seq, cdt)
            out = {"moe": jax.vmap(one)(jnp.arange(cfg.n_layers - nd))}
            out["dense"] = jax.vmap(one)(jnp.arange(nd)) if nd else None
            return out
        if fam == "ssm":
            return jax.vmap(lambda _: init_rwkv_cache(cfg, batch, cdt))(jnp.arange(cfg.n_layers))
        if fam == "hybrid":
            return init_hybrid_cache(cfg, batch, seq, cdt)
        if fam == "audio":
            f = cfg.enc_dec.n_frames
            hd = cfg.resolved_head_dim
            self_c = jax.vmap(lambda _: init_kv_cache(cfg, batch, seq, cdt))(jnp.arange(cfg.n_layers))
            cross = (
                jnp.zeros((cfg.n_layers, batch, f, cfg.n_kv_heads, hd), cdt),
                jnp.zeros((cfg.n_layers, batch, f, cfg.n_kv_heads, hd), cdt),
            )
            return {"self": self_c, "cross": cross}
        raise ValueError(fam)

    # ------------------------------------------------------ input specs ----
    def input_specs(shape: ShapeConfig) -> dict:
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((b, _text_len(s) + 1), i32)}
            specs.update(_frontend_specs(b, s))
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, _text_len(s)), i32)}
            specs.update(_frontend_specs(b, s))
            return specs
        # decode: cache of capacity s + one token
        cache = jax.eval_shape(lambda: init_cache(b, s))
        return {
            "cache": cache,
            "token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }

    def _text_len(s):
        return s - cfg.vlm.n_patches if fam == "vlm" else s

    def _frontend_specs(b, s):
        if fam == "vlm":
            return {"patches": jax.ShapeDtypeStruct((b, cfg.vlm.n_patches, cfg.d_model), dtype)}
        if fam == "audio":
            return {"frames": jax.ShapeDtypeStruct((b, cfg.enc_dec.n_frames, cfg.d_model), dtype)}
        return {}

    return SimpleNamespace(
        cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
        decode_step=decode_step, init_cache=init_cache, input_specs=input_specs,
    )
