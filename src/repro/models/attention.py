"""GQA/MHA attention block with KV cache (+ cross-attention for enc-dec)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical
from repro.models.layers import apply_rope, attention, chunked_attention, init_dense


def init_attention(key, cfg: ArchConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, (d, cfg.n_heads * hd), dtype),
        "wk": init_dense(k2, (d, cfg.n_kv_heads * hd), dtype),
        "wv": init_dense(k3, (d, cfg.n_kv_heads * hd), dtype),
        "wo": init_dense(k4, (cfg.n_heads * hd, d), dtype, scale=(cfg.n_heads * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }


def init_kv_cache(cfg: ArchConfig, batch: int, seq: int, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, seq, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, seq, cfg.n_kv_heads, hd), dtype),
    }


def attention_block(
    params,
    x,
    positions,
    cfg: ArchConfig,
    *,
    causal: bool = True,
    use_rope: bool = True,
    cache=None,
    cache_pos=None,
    cross_kv=None,
):
    """Returns (out, new_cache).

    train/prefill: x [B, S, D], cache None -> chunked flash attention.
    decode: x [B, 1, D], cache {k, v} of capacity S; writes at cache_pos.
    cross_kv: (k, v) from the encoder (cross-attention; ignores cache/rope).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    # constrain the *flattened* head dim (always divisible by the model axis,
    # unlike n_heads itself for e.g. 24-head phi4 on a 16-way TP axis)
    q = logical(x @ params["wq"], "batch", None, "heads")
    q = q.reshape(b, s, cfg.n_heads, hd)

    if cross_kv is not None:
        k, v = cross_kv
        out = attention(q, k, v, causal=False)
        out = logical(out.reshape(b, s, -1), "batch", None, "heads")
        return (out @ params["wo"]), cache

    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cache is None:
        # full-context attention reads all keys per head group: make the
        # gather from SP-sharded projections explicit (avoids the SPMD
        # "involuntary full rematerialization" resharding path)
        k = logical(k, "batch", None, "kv_heads", None)
        v = logical(v, "batch", None, "kv_heads", None)

    if cache is None:
        out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
    else:
        # single-token decode against a sequence-shardable cache
        cap = cache["k"].shape[1]
        pos = jnp.minimum(cache_pos, cap - 1)            # [B] int32
        wrt = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0)))
        k_cache = wrt(cache["k"], k.astype(cache["k"].dtype), pos)
        v_cache = wrt(cache["v"], v.astype(cache["v"].dtype), pos)
        k_cache = logical(k_cache, "batch", "kv_seq", "kv_heads", None)
        v_cache = logical(v_cache, "batch", "kv_seq", "kv_heads", None)
        kpos = jnp.arange(cap)[None, :]
        mask = (kpos <= pos[:, None])[:, None, None, :]  # [B,1,1,cap]
        out = attention(q, k_cache, v_cache, causal=False, mask=mask)
        cache = {"k": k_cache, "v": v_cache}
    out = logical(out.reshape(b, s, -1), "batch", None, "heads")
    return (out @ params["wo"]), cache
