"""RWKV-6 "Finch" block — attention-free, data-dependent per-channel decay.

Per head (dim P), state S [P_k, P_v]:
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t         w_t = exp(-exp(wdec_t))
    o_t = r_t . (S_{t-1} + diag(u) k_t (x) v_t)
with w_t data-dependent through a low-rank MLP (the V6 headline feature).
Time mixing uses the V6 token-shift; channel mixing is the standard RWKV
squared-ReLU FFN.  Sequential lax.scan over time (decode is the same cell).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical
from repro.models.layers import init_dense, rms_norm


def init_rwkv(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    lora = max(32, d // 32)
    p = {
        "time": {
            "mix": 0.5 * jnp.ones((5, d), dtype),                  # r,k,v,w,g shift mixes
            "wr": init_dense(ks[0], (d, d), dtype),
            "wk": init_dense(ks[1], (d, d), dtype),
            "wv": init_dense(ks[2], (d, d), dtype),
            "wg": init_dense(ks[3], (d, d), dtype),
            "w0": jnp.full((d,), -6.0, jnp.float32),               # base log-log decay
            "w_lora_a": init_dense(ks[4], (d, lora), dtype, scale=0.01),
            "w_lora_b": init_dense(ks[5], (lora, d), dtype, scale=0.01),
            "u": jnp.zeros((d,), jnp.float32),                     # bonus
            "wo": init_dense(ks[6], (d, d), dtype, scale=d**-0.5 / (2 * cfg.n_layers) ** 0.5),
            "ln_x": jnp.zeros((d,), dtype),
        },
        "channel": {
            "mix": 0.5 * jnp.ones((2, d), dtype),
            "wk": init_dense(ks[7], (d, cfg.d_ff), dtype),
            "wv": init_dense(ks[8], (cfg.d_ff, d), dtype,
                             scale=cfg.d_ff**-0.5 / (2 * cfg.n_layers) ** 0.5),
            "wr": init_dense(ks[9], (d, d), dtype),
        },
    }
    return p


def init_rwkv_cache(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    h = d // hd
    return {
        "state": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_time": jnp.zeros((batch, d), dtype),
        "x_chan": jnp.zeros((batch, d), dtype),
    }


def _token_shift(x, last=None):
    """previous-token features; `last` seeds position -1 (decode cache)."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, state, chunk: int = 64):
    """r,k,v [B,T,H,P]; w [B,T,H,P] decay in (0,1); state [B,H,P,P].

    Two-level scan: outer scan over time chunks with a rematerialized body,
    inner scan over steps.  Plain one-level autodiff would save the
    [B,H,P,P] state for *every* timestep (43 GiB/device for the 4k train
    cell); chunked remat keeps only one carry per chunk.
    """
    t = r.shape[1]
    q = min(chunk, t)
    pad = (-t) % q
    def prep(a, fill):
        return jnp.moveaxis(jnp.pad(a.astype(jnp.float32),
                                    ((0, 0), (0, pad), (0, 0), (0, 0)),
                                    constant_values=fill), 1, 0)
    # pad decay with 1 (identity) so the carried state survives padding
    xs = (prep(r, 0), prep(k, 0), prep(v, 0), prep(w, 1))
    nc = (t + pad) // q

    def step(s, inp):
        rt, kt, vt, wt = inp                                  # [B,H,P]
        kv = kt[..., :, None] * vt[..., None, :]              # [B,H,P,P]
        out = jnp.einsum("bhp,bhpq->bhq", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, out

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(s, inp):
        return jax.lax.scan(step, s, inp)

    xs_c = tuple(a.reshape(nc, q, *a.shape[1:]) for a in xs)
    state, outs = jax.lax.scan(chunk_body, state, xs_c)
    outs = outs.reshape(nc * q, *outs.shape[2:])[:t]
    return jnp.moveaxis(outs, 0, 1), state                    # [B,T,H,P]


def _wkv_chunked_parallel(r, k, v, w, u, state, chunk: int = 16):
    """Chunked *parallel* WKV (§Perf hillclimb, EXPERIMENTS.md).

    The sequential scan reads+writes the [B,H,P,P] state every timestep —
    the dominant HBM traffic of the whole model (memory-roofline term).
    Within a chunk the recurrence unrolls to an attention-like quadratic
    form with per-channel decay ratios computed stably in log space:

        out_t = (r_t . W_{t-1}) S_in  +  sum_{s<t} [r_t k_s exp(L_{t-1}-L_s)] v_s
                + (r_t . u . k_t) v_t
        S_out = exp(L_Q) . S_in + sum_s exp(L_Q - L_s) k_s (x) v_s

    so the state is touched once per chunk (HBM traffic / chunk) and the
    inner products run on the MXU.  Exponents are clamped at +-30 — decays
    small enough to underflow contribute nothing by construction.
    """
    b, t, h, pdim = r.shape
    q = min(chunk, t)
    pad = (-t) % q
    def prep(a, fill=0.0):
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=fill)
        return jnp.moveaxis(a.reshape(b, -1, q, h, pdim), 1, 0)   # [nc,B,Q,H,P]
    rc, kc, vc, wc = prep(r), prep(k), prep(v), prep(w, 1.0)
    # factorization exp(L_{t-1}-L_s) = exp(L_{t-1}) exp(-L_s) is exact while
    # |L| <= clamp: chunk 16 x per-step log-decay >= -3 stays within +-48
    # (covers RWKV's w = exp(-exp(.)) init and trained regimes; the "scan"
    # mode remains the exact fallback for pathological decays)
    clamp = lambda x: jnp.clip(x, -50.0, 50.0)
    tri = (jnp.arange(q)[:, None] > jnp.arange(q)[None, :])[None, None, :, :]

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def per_chunk(s_in, inp):
        # decay factors computed per chunk (f32) so no whole-sequence f32
        # tensors are ever materialized (peak-memory §Perf iteration);
        # rematerialized in backward — only the chunk state carry is saved
        rq, kq, vq, wq = (a.astype(jnp.float32) for a in inp)     # [B,Q,H,P]
        logw = jnp.log(jnp.maximum(wq, 1e-38))
        lc = jnp.cumsum(logw, axis=1)                    # L_t (inclusive)
        rdq = rq * jnp.exp(clamp(lc - logw))             # r_t exp(L_{t-1})
        kdq = kq * jnp.exp(clamp(-lc))                   # k_s exp(-L_s)
        # inter-chunk: r_t exp(L_{t-1}) @ S_in
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", rdq, s_in)
        # intra-chunk quadratic form with strict lower-triangular mask
        att = jnp.einsum("bqhk,bshk->bhqs", rdq, kdq)
        att = jnp.where(tri, att, 0.0)                   # [B,H,Q,S]
        y_intra = jnp.einsum("bhqs,bshv->bqhv", att, vq)
        # bonus diagonal: (r_t . u . k_t) v_t
        y_diag = jnp.sum(rq * u[None, None] * kq, -1, keepdims=True) * vq
        # state update
        l_q = lc[:, -1:, :, :]                           # L_Q
        k_out = kq * jnp.exp(clamp(l_q - lc))
        s_out = jnp.exp(clamp(l_q[:, 0]))[..., :, None] * s_in \
            + jnp.einsum("bshk,bshv->bhkv", k_out, vq)
        return s_out, (y_inter + y_intra + y_diag).astype(r.dtype)

    state, ys = jax.lax.scan(per_chunk, state, (rc, kc, vc, wc))
    out = jnp.moveaxis(ys, 0, 1).reshape(b, -1, h, pdim)[:, :t]
    return out, state


def rwkv_time_mix(p, x, cfg: ArchConfig, cache=None):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    h = d // hd
    b, t, _ = x.shape
    last = cache["x_time"] if cache is not None else None
    xprev = _token_shift(x, last)
    mix = p["mix"]
    xr = x + (xprev - x) * mix[0]
    xk = x + (xprev - x) * mix[1]
    xv = x + (xprev - x) * mix[2]
    xw = x + (xprev - x) * mix[3]
    xg = x + (xprev - x) * mix[4]
    r = (xr @ p["wr"]).reshape(b, t, h, hd)
    k = (xk @ p["wk"]).reshape(b, t, h, hd)
    v = (xv @ p["wv"]).reshape(b, t, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (V6): w = exp(-exp(w0 + lora(xw)))
    dec = p["w0"][None, None, :] + (jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, hd)
    u = p["u"].reshape(h, hd)
    state = cache["state"] if cache is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    if cache is None and cfg.rwkv.wkv_mode == "chunked":
        out, new_state = _wkv_chunked_parallel(r, k, v, w, u, state)
    else:
        out, new_state = _wkv_scan(r, k, v, w, u, state)
    out = rms_norm(out.reshape(b, t, d).astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = (out * g) @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state, "x_time": x[:, -1, :]}
    return out, new_cache


def rwkv_channel_mix(p, x, cfg: ArchConfig, cache=None):
    last = cache["x_chan"] if cache is not None else None
    xprev = _token_shift(x, last)
    xk = x + (xprev - x) * p["mix"][0]
    xr = x + (xprev - x) * p["mix"][1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    k = logical(k, "batch", None, "ffn")
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, (x[:, -1, :] if cache is not None else None)


def rwkv_block(params, x, cfg: ArchConfig, *, cache=None):
    tm, tc = rwkv_time_mix(params["time"], x, cfg, cache)
    x = x + tm
    cm, cc = rwkv_channel_mix(params["channel"], x, cfg, cache)
    x = x + cm
    new_cache = None
    if cache is not None:
        new_cache = {**tc, "x_chan": cc}
    return x, new_cache
