"""Mamba-2 (SSD) block — chunked state-space scan.

Recurrence per head h with state [N, P]:
    S_t = a_t * S_{t-1} + dt_t * (B_t  (x) x_t)      a_t = exp(dt_t * A_h)
    y_t = C_t^T S_t + D_h * x_t

Chunked formulation (Mamba-2 paper): a single lax.scan over chunks carries
the inter-chunk state; within a chunk the contribution is an attention-like
quadratic form masked by cumulative decay, so the transient is
[B, Q, Q, H] per chunk instead of [B, T, H, N, P] for a full associative
scan — the memory property that makes prefill_32k lowerable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical
from repro.models.layers import init_dense, rms_norm


def init_mamba(key, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    din = s.expand * d
    h = din // s.head_dim
    ks = jax.random.split(key, 8)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * din), dtype),              # x, z
        "w_bc": init_dense(ks[1], (d, 2 * s.state_dim), dtype),         # B, C
        "w_dt": init_dense(ks[2], (d, h), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),                          # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv": init_dense(ks[3], (s.conv_width, din), dtype, scale=0.5),
        "norm": jnp.zeros((din,), dtype),
        "out_proj": init_dense(ks[4], (din, d), dtype,
                               scale=din**-0.5 / (2 * cfg.n_layers) ** 0.5),
    }


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    din = s.expand * cfg.d_model
    h = din // s.head_dim
    return {
        "state": jnp.zeros((batch, h, s.state_dim, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, din), dtype),
    }


def _causal_conv(xs, conv_w, conv_state=None):
    """Depthwise causal conv, width W.  xs [B,T,din], conv_w [W,din]."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xs.shape[0], w - 1, xs.shape[2]), xs.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xs], axis=1)
    out = sum(xp[:, i : i + xs.shape[1], :] * conv_w[i][None, None, :] for i in range(w))
    new_state = xp[:, -(w - 1):, :] if w > 1 else None
    return out, new_state


def _ssd_chunked(xh, bt, ct, dt, a_log, chunk: int):
    """xh [B,T,H,P], bt/ct [B,T,N], dt [B,T,H] (post-softplus).  f32 scan."""
    b, t, h, p = xh.shape
    n = bt.shape[-1]
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bt = jnp.pad(bt, ((0, 0), (0, pad), (0, 0)))
        ct = jnp.pad(ct, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nc = tp // q
    a = -jnp.exp(a_log)                                     # [H]
    loga = dt * a[None, None, :]                            # [B,T,H] log-decay
    xc = xh.reshape(b, nc, q, h, p).astype(jnp.float32)
    bc = bt.reshape(b, nc, q, n).astype(jnp.float32)
    cc = ct.reshape(b, nc, q, n).astype(jnp.float32)
    dc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    lc = loga.reshape(b, nc, q, h).astype(jnp.float32)

    def per_chunk(state, inputs):
        xq, bq, cq, dq, lq = inputs                          # [B,Q,...]
        cla = jnp.cumsum(lq, axis=1)                         # [B,Q,H]
        # inter-chunk: y_i += C_i . (exp(cla_i) * S_in)
        decay_in = jnp.exp(cla)                              # [B,Q,H]
        y_inter = jnp.einsum("bqn,bhnp->bqhp", cq, state) * decay_in[..., None]
        # intra-chunk quadratic form
        g = jnp.einsum("bqn,bkn->bqk", cq, bq)               # [B,Q,Q]
        dd = cla[:, :, None, :] - cla[:, None, :, :]         # [B,Q,K,H]
        mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[None, :, :, None]
        w = jnp.where(mask, jnp.exp(dd) * g[..., None], 0.0) * dq[:, None, :, :]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w, xq)
        # state update: S_out = exp(cla_Q) * S_in + sum_j exp(cla_Q - cla_j) dt_j B_j (x) x_j
        decay_out = jnp.exp(cla[:, -1:, :] - cla)            # [B,Q,H]
        sb = jnp.einsum("bqh,bqn,bqhp->bhnp", decay_out * dq, bq, xq)
        state = jnp.exp(cla[:, -1, :])[:, :, None, None] * state + sb
        return state, y_inter + y_intra

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = tuple(jnp.moveaxis(arr, 1, 0) for arr in (xc, bc, cc, dc, lc))
    state, ys = jax.lax.scan(per_chunk, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, tp, h, p)[:, :t]
    return y, state


def mamba_block(params, x, cfg: ArchConfig, *, cache=None):
    """x [B, T, D] -> (out, new_cache)."""
    s = cfg.ssm
    b, t, d = x.shape
    din = s.expand * d
    h = din // s.head_dim
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = logical(xs, "batch", None, "heads")
    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, params["conv"], conv_state)
    xs = jax.nn.silu(xs)
    bc = x @ params["w_bc"]
    bt, ct = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(x.astype(jnp.float32) @ params["w_dt"].astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    xh = xs.reshape(b, t, h, s.head_dim)

    if cache is None:
        y, _ = _ssd_chunked(xh, bt, ct, dt, params["a_log"], s.chunk)
        new_cache = None
    else:
        # single-step recurrence
        a = -jnp.exp(params["a_log"])
        decay = jnp.exp(dt[:, 0] * a[None, :])               # [B,H]
        sb = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0], bt[:, 0].astype(jnp.float32),
                        xh[:, 0].astype(jnp.float32))
        state = decay[:, :, None, None] * cache["state"] + sb
        y = jnp.einsum("bn,bhnp->bhp", ct[:, 0].astype(jnp.float32), state)[:, None]
        new_cache = {"state": state, "conv": new_conv}

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], new_cache
