"""Mixture-of-Experts FFN with expert parallelism via shard_map.

EP strategy (DESIGN.md §5): token activations are replicated over the
"model" mesh axis at the MoE boundary, experts are sharded over it.  Each
device gathers (up to capacity C) the tokens routed to *its* local experts —
zero dispatch communication — computes the expert FFNs, scatters back, and a
single psum over "model" combines, i.e. the same collective footprint as a
tensor-parallel FFN.  Shared experts run as an ordinary TP SwiGLU outside
the shard_map.

Routers: 'softmax' (DeepSeek-V2: softmax then top-k, aux load-balance loss)
and 'sigmoid_bias' (DeepSeek-V3: sigmoid scores, bias-adjusted top-k
selection, aux-free; the bias is a non-trainable param updated from expert
load by the trainer).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ArchConfig
from repro.distributed.sharding import active_rules, logical
from repro.models.layers import init_dense, swiglu


def init_moe(key, cfg: ArchConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    shared_ff = m.shared_d_ff or m.n_shared * m.expert_d_ff
    return {
        "router": {"w": init_dense(ks[0], (d, m.n_experts), jnp.float32),
                   "bias": jnp.zeros((m.n_experts,), jnp.float32)},
        "experts": {
            "w1": init_dense(ks[1], (m.n_experts, d, m.expert_d_ff), dtype),
            "w3": init_dense(ks[2], (m.n_experts, d, m.expert_d_ff), dtype),
            "w2": init_dense(ks[3], (m.n_experts, m.expert_d_ff, d), dtype,
                             scale=m.expert_d_ff**-0.5 / (2 * cfg.n_layers) ** 0.5),
        },
        "shared": {
            "w1": init_dense(ks[4], (d, shared_ff), dtype),
            "w3": init_dense(ks[5], (d, shared_ff), dtype),
            "w2": init_dense(ks[6], (shared_ff, d), dtype,
                             scale=shared_ff**-0.5 / (2 * cfg.n_layers) ** 0.5),
        },
    }


def _route(x_flat, router_w, router_bias, cfg: ArchConfig):
    """Full-E routing decision. Returns (weights [t,k], idx [t,k], probs [t,E])."""
    m = cfg.moe
    logits = (x_flat.astype(jnp.float32) @ router_w).astype(jnp.float32)
    if m.router == "sigmoid_bias":
        scores = jax.nn.sigmoid(logits)
        sel_scores = scores + router_bias[None, :]
        _, idx = jax.lax.top_k(sel_scores, m.top_k)
        w = jnp.take_along_axis(scores, idx, axis=1)
        if m.norm_topk_prob:
            w = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-20)
        probs = scores
    else:
        probs = jax.nn.softmax(logits, axis=1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        if m.norm_topk_prob:
            w = w / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1e-20)
    return w, idx, probs


def _routed_ffn_local(x_flat, gate, w1, w3, w2, capacity: int):
    """Capacity-C per-expert gather -> SwiGLU -> weighted scatter-add.

    x_flat [t, d]; gate [t, E_loc] (combine weight, 0 if not routed);
    expert weights [E_loc, d, f] / [E_loc, f, d].
    """
    t = x_flat.shape[0]
    c = min(capacity, t)
    sel_w, sel_idx = jax.lax.top_k(gate.T, c)            # [E_loc, C]
    x_sel = x_flat[sel_idx]                              # [E_loc, C, d]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_sel, w1)) * jnp.einsum("ecd,edf->ecf", x_sel, w3)
    y = jnp.einsum("ecf,efd->ecd", h, w2)
    y = y * sel_w[..., None].astype(y.dtype)
    out = jnp.zeros_like(x_flat).at[sel_idx.reshape(-1)].add(y.reshape(-1, x_flat.shape[1]))
    return out


def _gate_matrix(weights, idx, e_offset, e_loc: int):
    """[t, E_loc] combine-weight matrix for this shard's expert range."""
    local = idx[..., None] - e_offset                    # [t, k, 1]
    onehot = (local == jnp.arange(e_loc)[None, None, :]).astype(weights.dtype)
    return jnp.einsum("tk,tke->te", weights, onehot)


def _moe_shard(x, router_w, router_bias, w1, w3, w2, *, cfg: ArchConfig,
               capacity: int, axis: str):
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    weights, idx, probs = _route(x_flat, router_w, router_bias, cfg)
    e_loc = w1.shape[0]
    e_offset = jax.lax.axis_index(axis) * e_loc
    gate = _gate_matrix(weights, idx, e_offset, e_loc)
    y = _routed_ffn_local(x_flat, gate, w1, w3, w2, capacity)
    y = jax.lax.psum(y, axis)
    # aux load-balance statistics (global over the data axes happens outside)
    m = cfg.moe
    load = jnp.mean(jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32), axis=(0, 1)) * m.n_experts
    imp = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(load / m.n_experts * imp)
    return y.reshape(b, s, d), aux, load


def moe_block(params, x, cfg: ArchConfig):
    """Returns (out, aux) where aux = {'aux_loss', 'expert_load'}."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    rules = active_rules()
    router = params["router"]
    ex = params["experts"]
    shared_out = swiglu(x, **params["shared"])

    ep_axis = rules.axis("experts") if rules is not None else None
    if ep_axis is not None and rules.mesh.shape[ep_axis] > 1 and m.n_experts % rules.mesh.shape[ep_axis] == 0:
        ep = rules.mesh.shape[ep_axis]
        t_local = t // _dp_size(rules)
        capacity = max(1, int(t_local * m.top_k / m.n_experts * m.capacity_factor))
        batch_ax = rules.axis("batch")
        fn = functools.partial(_moe_shard, cfg=cfg, capacity=capacity, axis=ep_axis)
        y, aux, load = shard_map(
            fn,
            mesh=rules.mesh,
            in_specs=(P(batch_ax, None, None), P(), P(),
                      P(ep_axis, None, None), P(ep_axis, None, None), P(ep_axis, None, None)),
            out_specs=(P(batch_ax, None, None), P(), P()),
            check_vma=False,
        )(x, router["w"], router["bias"], ex["w1"], ex["w3"], ex["w2"])
    else:
        capacity = max(1, int(t * m.top_k / m.n_experts * m.capacity_factor))
        x_flat = x.reshape(-1, d)
        weights, idx, probs = _route(x_flat, router["w"], router["bias"], cfg)
        gate = _gate_matrix(weights, idx, 0, m.n_experts)
        y = _routed_ffn_local(x_flat, gate, ex["w1"], ex["w3"], ex["w2"], capacity).reshape(b, s, d)
        load = jnp.mean(jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32), axis=(0, 1)) * m.n_experts
        imp = jnp.mean(probs, axis=0)
        aux = m.n_experts * jnp.sum(load / m.n_experts * imp)

    out = shared_out + y.astype(x.dtype)
    return out, {"aux_loss": aux.astype(jnp.float32), "expert_load": load}


def _dp_size(rules):
    ax = rules.axis("batch")
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    size = 1
    for a in axes:
        size *= rules.mesh.shape[a]
    return size
