"""Multi-head Latent Attention (DeepSeek V2/V3).

Train/prefill materializes per-head K/V from the latent (standard path,
chunked flash attention).  Decode uses the *absorbed* formulation: W_uk is
folded into the query and W_uv into the output so attention runs directly
against the cached latent c_kv [B, S, r] + shared k_rope [B, S, dr] — the
MLA KV-cache compression that motivates the architecture (cache is
r + dr = 576 floats/token instead of 2 * H * dh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical
from repro.models.layers import apply_rope, chunked_attention, init_dense, rms_norm


def init_mla(key, cfg: ArchConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    keys = jax.random.split(key, 8)
    qin = m.q_lora_rank or d
    p = {
        "w_dkv": init_dense(keys[0], (d, m.kv_lora_rank), dtype),
        "kv_ln": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_krope": init_dense(keys[1], (d, m.qk_rope_head_dim), dtype),
        "w_uk": init_dense(keys[2], (m.kv_lora_rank, h * m.qk_nope_head_dim), dtype),
        "w_uv": init_dense(keys[3], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "w_uq": init_dense(keys[4], (qin, h * (m.qk_nope_head_dim + m.qk_rope_head_dim)), dtype),
        "wo": init_dense(keys[5], (h * m.v_head_dim, d), dtype,
                         scale=(h * m.v_head_dim) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    if m.q_lora_rank:
        p["w_dq"] = init_dense(keys[6], (d, m.q_lora_rank), dtype)
        p["q_ln"] = jnp.zeros((m.q_lora_rank,), dtype)
    return p


def init_mla_cache(cfg: ArchConfig, batch: int, seq: int, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype),
    }


def _queries(params, x, positions, cfg: ArchConfig):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if m.q_lora_rank:
        cq = rms_norm(x @ params["w_dq"], params["q_ln"], cfg.norm_eps)
    else:
        cq = x
    q = (cq @ params["w_uq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_block(params, x, positions, cfg: ArchConfig, *, cache=None, cache_pos=None):
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _queries(params, x, positions, cfg)
    ckv = rms_norm(x @ params["w_dkv"], params["kv_ln"], cfg.norm_eps)  # [B,S,r]
    krope = apply_rope((x @ params["w_krope"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        # materialized path (train / prefill): per-head K,V from the latent
        k_nope = (ckv @ params["w_uk"]).reshape(b, s, h, m.qk_nope_head_dim)
        v = (ckv @ params["w_uv"]).reshape(b, s, h, m.v_head_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(krope[:, :, None, :], (b, s, h, m.qk_rope_head_dim))], axis=-1)
        # pad V to the QK head dim so the flash kernel sees uniform shapes
        out = chunked_attention(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, q.shape[-1] - v.shape[-1]))),
                                causal=cfg.causal, chunk=cfg.attn_chunk)
        out = out[..., : m.v_head_dim]
        new_cache = None
    else:
        # absorbed decode against the latent cache
        cap = cache["ckv"].shape[1]
        pos = jnp.minimum(cache_pos, cap - 1)            # [B] int32
        wrt = jax.vmap(lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0)))
        ckv_c = wrt(cache["ckv"], ckv.astype(cache["ckv"].dtype), pos)
        kr_c = wrt(cache["krope"], krope.astype(cache["krope"].dtype), pos)
        ckv_c = logical(ckv_c, "batch", "latent_seq", None)
        kr_c = logical(kr_c, "batch", "latent_seq", None)
        w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)           # absorb W_uk
        scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
        logits = (
            jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), ckv_c.astype(jnp.float32))
            + jnp.einsum("bshe,bte->bhst", q_rope.astype(jnp.float32), kr_c.astype(jnp.float32))
        ) * scale
        mask = (jnp.arange(cap)[None, :] <= pos[:, None])[:, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs, ckv_c.astype(jnp.float32)).astype(x.dtype)
        w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_uv)            # absorb W_uv
        new_cache = {"ckv": ckv_c, "krope": kr_c}

    out = logical(out.reshape(b, s, -1), "batch", None, "heads")
    return (out @ params["wo"]), new_cache
