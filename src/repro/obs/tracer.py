"""Hierarchical span tracer: the timing substrate of the obs layer.

The source paper's analysis lives on per-step breakdowns (Tables 5/6):
knowing *which* phase dominates is what directed every optimization.  This
module makes that analysis reproducible on our own hot paths — every phase
of a fit (knn / bsp / symmetrize / gradient_descent), a transform batch, or
a service tick opens a :class:`Span`:

    tracer = Tracer()
    with tracer.span("knn") as sp:
        idx, d2 = backend.neighbors(x, k)
        sp.sync(idx)            # block_until_ready at span exit
    tracer.durations()["knn"]   # seconds

Two properties matter on a JAX hot path:

* **device-sync-aware timing** — JAX dispatch is asynchronous, so a naive
  ``perf_counter`` pair around a jitted call times the *dispatch*, not the
  work, and the cost surfaces inside whatever phase blocks next.
  ``sp.sync(arrays)`` registers a pytree whose ``block_until_ready`` runs
  at span exit, *before* the end timestamp is taken, so work is attributed
  to the phase that launched it.
* **near-zero disabled overhead** — a disabled tracer's ``span()`` returns
  one reusable no-op context manager (no allocation, no clock read), so
  instrumentation can stay in production code unconditionally.

Spans nest through a per-thread stack; each completed span records its
parent index and depth, which the exporters turn into a hierarchy:
:meth:`Tracer.to_jsonl` writes one JSON object per span, and
:meth:`Tracer.to_chrome_trace` writes Chrome-trace JSON (``traceEvents``
with ``ph: "X"`` complete events) loadable in Perfetto / ``chrome://tracing``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable


class _NullSpan:
    """Reusable no-op span: the entire disabled-mode surface."""

    __slots__ = ()
    enabled = False

    def annotate(self, **attrs) -> "_NullSpan":
        return self

    def sync(self, value):
        return value

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One timed region.  Created by :meth:`Tracer.span`; closed by the
    context manager, which first blocks on every pytree registered through
    :meth:`sync` so asynchronously dispatched device work lands inside the
    span that launched it."""

    __slots__ = ("name", "t0", "t1", "depth", "index", "parent", "attrs",
                 "_sync_targets")
    enabled = True

    def __init__(self, name: str, t0: float, depth: int, index: int,
                 parent: int, attrs: dict):
        self.name = name
        self.t0 = t0
        self.t1: float | None = None
        self.depth = depth
        self.index = index
        self.parent = parent          # index of enclosing span, -1 at root
        self.attrs = attrs
        self._sync_targets: list = []

    def annotate(self, **attrs) -> "Span":
        """Attach key/value metadata (lands in ``args`` of the trace event)."""
        self.attrs.update(attrs)
        return self

    def sync(self, value):
        """Register a pytree to ``block_until_ready`` at span exit; returns
        ``value`` unchanged so it can wrap an expression in place."""
        self._sync_targets.append(value)
        return value

    @property
    def duration_s(self) -> float:
        if self.t1 is None:
            raise RuntimeError(f"span {self.name!r} is still open")
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        d = dict(name=self.name, ts=self.t0, dur=self.duration_s,
                 depth=self.depth, index=self.index, parent=self.parent)
        if self.attrs:
            d["attrs"] = self.attrs
        return d


class _SpanCtx:
    """Binds one Span to a (tracer, thread-stack) for with-statement use."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        return self._span

    def __exit__(self, *exc) -> bool:
        self._tracer._pop(self._span)
        return False


class Tracer:
    """Collects nested spans; export through :meth:`to_jsonl` /
    :meth:`to_chrome_trace`, aggregate through :meth:`durations`.

    Thread-safe: each thread nests on its own stack (Chrome-trace ``tid``),
    completed spans append under a lock.  ``clock`` is injectable for
    deterministic tests.
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self._clock = clock
        self.spans: list[Span] = []      # completed, in close order
        self._local = threading.local()
        self._lock = threading.Lock()
        self._n_started = 0
        self.t_epoch = clock()           # ts base for exported traces

    # ------------------------------------------------------------ record --

    def _stack(self) -> list[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs):
        """Open a span named ``name``.  Disabled tracers return the shared
        no-op span — zero allocation, no clock read."""
        if not self.enabled:
            return NULL_SPAN
        stack = self._stack()
        parent = stack[-1].index if stack else -1
        with self._lock:
            index = self._n_started
            self._n_started += 1
        sp = Span(name, self._clock(), depth=len(stack), index=index,
                  parent=parent, attrs=dict(attrs))
        return _SpanCtx(self, sp)

    def _push(self, sp: Span) -> None:
        self._stack().append(sp)

    def _pop(self, sp: Span) -> None:
        if sp._sync_targets:
            import jax
            for target in sp._sync_targets:
                jax.block_until_ready(target)
            sp._sync_targets.clear()
        sp.t1 = self._clock()
        stack = self._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        with self._lock:
            self.spans.append(sp)

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()

    def _snapshot(self) -> list[Span]:
        """Consistent copy of the completed spans (``_pop`` appends from
        worker threads under the same lock)."""
        with self._lock:
            return list(self.spans)

    # ----------------------------------------------------------- inspect --

    def find(self, name: str) -> list[Span]:
        return [s for s in self._snapshot() if s.name == name]

    def last(self, name: str) -> Span | None:
        for s in reversed(self._snapshot()):
            if s.name == name:
                return s
        return None

    def durations(self) -> dict[str, float]:
        """Total seconds per span name (summed over occurrences)."""
        out: dict[str, float] = {}
        for s in self._snapshot():
            out[s.name] = out.get(s.name, 0.0) + s.duration_s
        return out

    # ------------------------------------------------------------ export --

    def to_jsonl(self, path) -> None:
        """One JSON object per completed span (ts/dur in seconds, relative
        to the tracer epoch)."""
        with open(path, "w") as f:
            for s in self._snapshot():
                d = s.to_dict()
                d["ts"] = d["ts"] - self.t_epoch
                f.write(json.dumps(d) + "\n")

    def chrome_trace(self, process_name: str = "tsne") -> dict:
        """Chrome-trace dict: ``traceEvents`` of complete (``ph: "X"``)
        events, one per span, ts/dur in microseconds.  Nesting is implied by
        time containment per ``tid``, which holds because spans nest on a
        per-thread stack."""
        pid = os.getpid()
        events: list[dict] = [dict(
            name="process_name", ph="M", pid=pid, tid=0,
            args=dict(name=process_name),
        )]
        for s in sorted(self._snapshot(), key=lambda s: s.t0):
            ev = dict(
                name=s.name, ph="X", pid=pid, tid=0, cat="phase",
                ts=round((s.t0 - self.t_epoch) * 1e6, 3),
                dur=round(s.duration_s * 1e6, 3),
            )
            if s.attrs:
                ev["args"] = {k: _jsonable(v) for k, v in s.attrs.items()}
            events.append(ev)
        return dict(traceEvents=events, displayTimeUnit="ms")

    def to_chrome_trace(self, path, process_name: str = "tsne") -> None:
        """Write :meth:`chrome_trace` JSON, loadable in Perfetto."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(process_name), f)


def _jsonable(v: Any):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return float(v)       # numpy / jax scalars
    except (TypeError, ValueError):
        return str(v)
