"""Unified observability layer: spans, metrics, recompile probes, sinks.

The paper's methodology in library form — per-phase breakdowns (its
Tables 5/6) as first-class, reproducible artifacts:

* :class:`Tracer` / :class:`Span` — hierarchical, device-sync-aware
  timing (``sp.sync(arrays)`` blocks at span exit so async JAX dispatch
  is attributed to the phase that launched it);
* :class:`MetricsRegistry` — counters, gauges, bounded histograms with
  p50/p95/p99;
* :class:`RecompileProbe` — one count per distinct jit trace;
* sinks — JSONL event logs and Chrome-trace JSON (Perfetto-loadable).

Everything is **disabled by default with near-zero overhead**.  Three ways
to turn tracing on:

* ``TSNE(trace=True)`` (or ``trace="fit_trace.json"`` to also write the
  Chrome trace) — per-estimator tracer, exposed as ``est.tracer_``;
* ``TSNE_TRACE=1`` in the environment — enables the process-global tracer
  that instrumented code uses when no explicit tracer is passed;
* ``--trace`` on ``benchmarks/run.py`` and
  ``python -m repro.embed.service --smoke --trace out.json``.

The process-global instruments live here: :func:`get_tracer` /
:func:`get_metrics` (used by instrumented modules when not handed an
explicit tracer), :func:`set_tracer` to swap in an enabled one.
"""
from __future__ import annotations

import os

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracer import NULL_SPAN, Span, Tracer


def env_trace_enabled() -> bool:
    """True when the ``TSNE_TRACE`` env var requests tracing (any value
    but empty / ``0`` / ``false`` / ``off``)."""
    v = os.environ.get("TSNE_TRACE", "").strip().lower()
    return v not in ("", "0", "false", "off")


_global_tracer = Tracer(enabled=env_trace_enabled())
_global_metrics = MetricsRegistry()


def get_tracer() -> Tracer:
    """The process-global tracer (disabled unless ``TSNE_TRACE`` is set or
    :func:`set_tracer` installed an enabled one)."""
    return _global_tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-global tracer; returns it."""
    global _global_tracer
    _global_tracer = tracer
    return tracer


def get_metrics() -> MetricsRegistry:
    """The process-global metrics registry (always collecting — metric
    updates are a few arithmetic ops, never device syncs)."""
    return _global_metrics


def trace(name: str, **attrs):
    """Open a span on the global tracer — ``with trace("knn") as sp:``.
    A no-op (shared null span) while the global tracer is disabled."""
    return _global_tracer.span(name, **attrs)


# imported late: RecompileProbe registers on the global metrics registry
from repro.obs.recompile import RecompileProbe  # noqa: E402

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_SPAN", "Span", "Tracer", "RecompileProbe",
    "env_trace_enabled", "get_metrics", "get_tracer", "set_tracer", "trace",
]
