"""JAX recompile probe: count distinct ``(shape, static-args)`` traces.

Retracing is the silent performance killer of a jitted serving path — a
schedule value passed as a static argument, or a batch that isn't padded
to a fixed shape, quietly compiles a new program per variant.  The probe
is a trace-time side effect: call :meth:`RecompileProbe.record` with the
abstract shapes / static values *inside* the jitted function, and it runs
only when JAX traces (not on cached executions), so

    PROBE = RecompileProbe("transform_step")

    @jax.jit
    def step(x):
        PROBE.record(x.shape, x.dtype.name)
        ...

``PROBE.count`` is the number of *distinct* compiled variants, and stays
flat across calls that reuse a trace — the property the no-retrace tests
assert.  ``PROBE.calls`` counts every trace event (a cache-evicted retrace
of a seen key still increments it).  This replaces ad-hoc module-global
trace logs (the old ``TRACE_LOG`` list in ``repro.embed.transform``),
which grew unbounded and counted nothing.

Probes register on a :class:`~repro.obs.metrics.MetricsRegistry` (the
process-global one by default) as ``recompiles.<name>``, so service
telemetry snapshots include compile churn for free.
"""
from __future__ import annotations

import threading


class RecompileProbe:
    """Counts distinct trace keys of one jitted function."""

    def __init__(self, name: str, registry=None):
        self.name = name
        self._keys: set = set()
        self._calls = 0
        self._lock = threading.Lock()
        if registry is None:
            from repro.obs import get_metrics
            registry = get_metrics()
        self._counter = registry.counter(f"recompiles.{name}")

    def record(self, *key) -> None:
        """Record one trace event keyed by ``key`` (shapes, dtypes, static
        argument values — anything hashable).  Call inside the jitted
        function so it fires at trace time only."""
        with self._lock:
            self._calls += 1
            if key not in self._keys:
                self._keys.add(key)
                self._counter.inc()

    @property
    def count(self) -> int:
        """Distinct compiled variants seen (unique trace keys)."""
        with self._lock:
            return len(self._keys)

    @property
    def calls(self) -> int:
        """Total trace events, including re-traces of seen keys."""
        with self._lock:
            return self._calls

    @property
    def keys(self) -> frozenset:
        with self._lock:
            return frozenset(self._keys)

    def reset(self) -> None:
        with self._lock:
            self._keys.clear()
            self._calls = 0
