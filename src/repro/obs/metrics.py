"""Metrics registry: counters, gauges, and bounded histograms.

Spans (``repro.obs.tracer``) answer *where time went*; metrics answer
*how the system behaved* — queue depths, slot occupancy, request
latencies, gradient norms, recompile counts.  All instruments are
get-or-create by name on a :class:`MetricsRegistry`:

    m = MetricsRegistry()
    m.counter("embed.completed").inc()
    m.gauge("embed.queue_depth").set(len(queue))
    m.histogram("embed.latency_s").observe(req.latency_s)
    m.snapshot()        # plain dict, JSON-ready

Histograms keep a **bounded** sample reservoir (ring overwrite past
``max_samples``) so long-running services never grow unbounded, while
count/sum/min/max stay exact; percentiles (p50/p95/p99) are computed over
the retained window.  Registries merge (:meth:`MetricsRegistry.merge`):
counters add, gauges take the other's latest value, histograms pool their
retained samples — the worker-aggregation primitive.
"""
from __future__ import annotations

import math


class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value

    def merge(self, other: "Counter") -> None:
        self.value += other.value


class Gauge:
    """Last-set instantaneous value; tracks the high-water mark."""

    __slots__ = ("name", "value", "max_value", "n_sets")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0
        self.max_value: float = -math.inf
        self.n_sets = 0

    def set(self, v: float) -> float:
        self.value = float(v)
        self.n_sets += 1
        if self.value > self.max_value:
            self.max_value = self.value
        return self.value

    def merge(self, other: "Gauge") -> None:
        if other.n_sets:
            self.value = other.value
            self.n_sets += other.n_sets
        if other.max_value > self.max_value:
            self.max_value = other.max_value


class Histogram:
    """Bounded-reservoir value distribution.

    Exact ``count`` / ``sum`` / ``min`` / ``max`` over every observation;
    quantiles over the last ``max_samples`` observations (ring overwrite),
    so memory stays O(max_samples) for the life of a service.
    """

    __slots__ = ("name", "max_samples", "count", "total", "min", "max",
                 "_samples", "_next")

    def __init__(self, name: str, max_samples: int = 4096):
        if max_samples < 1:
            raise ValueError(f"max_samples={max_samples} must be >= 1")
        self.name = name
        self.max_samples = max_samples
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: list[float] = []
        self._next = 0                     # ring cursor once full

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._samples) < self.max_samples:
            self._samples.append(v)
        else:
            self._samples[self._next] = v
            self._next = (self._next + 1) % self.max_samples

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """q in [0, 100], linear interpolation over retained samples."""
        if not self._samples:
            return math.nan
        xs = sorted(self._samples)
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        if not self.count:
            return dict(count=0)
        return dict(
            count=self.count, mean=self.mean, min=self.min, max=self.max,
            p50=self.percentile(50), p95=self.percentile(95),
            p99=self.percentile(99),
        )

    def merge(self, other: "Histogram") -> None:
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        for v in other._samples:
            if len(self._samples) < self.max_samples:
                self._samples.append(v)
            else:
                self._samples[self._next] = v
                self._next = (self._next + 1) % self.max_samples


class MetricsRegistry:
    """Named instruments, get-or-create; snapshot() is JSON-ready."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, max_samples: int = 4096) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, max_samples)
        return h

    def counter_values(self, prefix: str = "") -> dict:
        """Current values of counters whose name starts with ``prefix``
        (e.g. ``counter_values("recompiles.")`` -> per-probe trace counts)."""
        return {name: c.value for name, c in sorted(self._counters.items())
                if name.startswith(prefix)}

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other`` in: counters add, gauges take other's last set
        value (high-water marks max), histograms pool retained samples."""
        for name, c in other._counters.items():
            self.counter(name).merge(c)
        for name, g in other._gauges.items():
            self.gauge(name).merge(g)
        for name, h in other._histograms.items():
            self.histogram(name, h.max_samples).merge(h)
        return self

    def snapshot(self) -> dict:
        out: dict = {}
        for name, c in sorted(self._counters.items()):
            out[name] = c.value
        for name, g in sorted(self._gauges.items()):
            out[name] = dict(value=g.value, max=g.max_value)
        for name, h in sorted(self._histograms.items()):
            out[name] = h.summary()
        return out

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()
