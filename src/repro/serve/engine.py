"""Batched serving engine with continuous batching.

A fixed pool of batch slots steps through ``decode_step`` together; slots
whose sequence finished (EOS or max tokens) are refilled from the request
queue between steps — the standard continuous-batching loop (vLLM-style),
sized down to run real tokens through the reduced configs on CPU.  The
same engine drives the decode-shape dry-run cells at production scale.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """``metrics`` (default: a private registry on ``self.metrics``)
    records the same telemetry shape as the embedding service:
    ``serve.queue_depth`` / ``serve.slot_occupancy`` gauges per tick,
    ``serve.ticks`` / ``serve.completed`` / ``serve.tokens`` counters, and
    a ``serve.request_tokens`` histogram at retirement — one dashboard
    vocabulary across both continuous-batching loops."""

    def __init__(self, model, batch_slots: int = 4, max_seq: int = 128,
                 eos_id: int | None = None, greedy: bool = True, seed: int = 0,
                 params: Any | None = None,
                 metrics: obs.MetricsRegistry | None = None):
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        self.model = model
        self.model_params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        # `queue` / `completed` may be touched from outside the engine
        # thread (submit while run() drains) — guarded by `_lock`.  Slot
        # state (`active`, `pos`, `next_token`, `cache`) is engine-owned.
        self._lock = threading.Lock()
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.next_token = np.zeros(batch_slots, np.int32)
        self.cache = model.init_cache(batch_slots, max_seq)
        self._step = jax.jit(model.decode_step)
        self.completed: list[Request] = []

    def submit(self, req: Request):
        with self._lock:
            self.queue.append(req)

    def _refill(self):
        for s in range(self.slots):
            if self.active[s] is None:
                with self._lock:
                    if not self.queue:
                        break
                    req = self.queue.popleft()
                self.active[s] = req
                # prefill-by-decode: feed prompt tokens one at a time into
                # this slot's cache rows (keeps a single compiled step fn)
                self.pos[s] = 0
                self.next_token[s] = req.prompt[0]
                req._prompt_cursor = 1  # type: ignore[attr-defined]

    def step(self):
        """One engine tick: decode_step over all slots, then bookkeeping."""
        if self.model_params is None:
            raise RuntimeError(
                "no model params — pass params= to ServeEngine(...) or call "
                "run(params) instead of stepping directly"
            )
        self._refill()
        occupancy = sum(a is not None for a in self.active)
        with self._lock:
            depth = len(self.queue)
        self.metrics.gauge("serve.queue_depth").set(depth)
        self.metrics.gauge("serve.slot_occupancy").set(occupancy)
        if occupancy == 0:
            return False
        self.metrics.counter("serve.ticks").inc()
        tok = jnp.asarray(self.next_token)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._step(self.model_params, self.cache, tok, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            cur = getattr(req, "_prompt_cursor", len(req.prompt))
            if cur < len(req.prompt):                 # still consuming prompt
                self.next_token[s] = req.prompt[cur]
                req._prompt_cursor = cur + 1          # type: ignore[attr-defined]
                continue
            token = int(nxt[s])
            req.generated.append(token)
            self.next_token[s] = token
            self.metrics.counter("serve.tokens").inc()
            if (self.eos_id is not None and token == self.eos_id) or \
               len(req.generated) >= req.max_new_tokens or \
               self.pos[s] >= self.max_seq - 1:
                req.done = True
                with self._lock:
                    self.completed.append(req)
                self.active[s] = None
                self.metrics.counter("serve.completed").inc()
                self.metrics.histogram("serve.request_tokens").observe(
                    len(req.generated))
        return True

    def run(self, params: Any | None = None, max_ticks: int = 10_000):
        if params is not None:
            self.model_params = params
        ticks = 0
        while ticks < max_ticks:
            with self._lock:
                pending = bool(self.queue)
            if not pending and all(a is None for a in self.active):
                break
            self.step()
            ticks += 1
        with self._lock:
            return list(self.completed)

    def stats(self) -> dict:
        """Telemetry snapshot (counters, gauge high-water marks, token
        histogram summary) for the life of the engine."""
        return self.metrics.snapshot()
