"""Pluggable t-SNE gradient backends + string-keyed registry.

A *backend* owns step 3-6 of the pipeline: given the current embedding, the
:class:`~repro.core.tsne.NeighborGraph` and the exaggeration factor, it
returns a :class:`~repro.core.tsne.GradResult` (gradient, KL estimate, Z).
Backends are frozen dataclasses — hashable, so ``tsne_step`` can treat them
as static jit arguments and each backend compiles its own step program.

Three first-class implementations ship with the repo:

* ``exact``       — the O(N^2) oracle (``core/exact.py``)
* ``barnes_hut``  — the paper's Morton/quadtree/summarize/traverse pipeline
* ``fft``         — FIt-SNE-style grid-interpolation repulsion
                    (``core/fft_repulsion.py``, Linderman et al.)

Register your own with :func:`register_backend`; the estimator's ``method=``
and ``TsneConfig.method`` both dispatch through :func:`make_backend`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import attractive, exact
from repro.core.fft_repulsion import fft_repulsion
from repro.core.tsne import (
    DEFAULT_ATTRACTIVE_IMPL, GradResult, NeighborGraph, TsneConfig, bh_gradient,
    combine_forces,
)


@runtime_checkable
class GradientBackend(Protocol):
    """What ``tsne_step`` needs from a backend.

    Implementations must be hashable (frozen dataclasses are) because the
    backend is passed to ``jax.jit`` as a static argument.
    """

    name: str

    def gradient(
        self, y: jax.Array, graph: NeighborGraph, exaggeration
    ) -> GradResult:
        ...


# --------------------------------------------------------------------------
# Shared attractive-term dispatch (exaggeration-free; callers scale it)
# --------------------------------------------------------------------------

def _attractive(y, graph: NeighborGraph, attractive_impl: str,
                attractive_block: int = 512):
    if attractive_impl == "edges":
        if not graph.has_edges:
            raise ValueError(
                "attractive_impl='edges' but the NeighborGraph carries no edge "
                "list — preprocess with TsneConfig(attractive_impl='edges')"
            )
        return attractive.attractive_forces_edges(y, *graph.edges)
    if graph.p_cols.shape[0] != y.shape[0]:
        raise ValueError(
            f"attractive_impl={attractive_impl!r} needs the ELL rows, but this "
            "NeighborGraph was preprocessed edges-only "
            "(attractive_impl='edges')"
        )
    if attractive_impl == "blocked":
        return attractive.attractive_forces_ell_blocked(
            y, graph.p_cols, graph.p_vals, block=attractive_block
        )
    return attractive.ell_impl(attractive_impl)(y, graph.p_cols, graph.p_vals)


# --------------------------------------------------------------------------
# First-class backends
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExactBackend:
    """O(N^2) dense gradient — the correctness oracle, feasible to ~5k points."""

    name: ClassVar[str] = "exact"

    def gradient(self, y, graph: NeighborGraph, exaggeration) -> GradResult:
        n = y.shape[0]
        if graph.p_cols.shape[0] != n:
            raise ValueError(
                "the exact backend needs the ELL rows, but this NeighborGraph "
                "was preprocessed edges-only (attractive_impl='edges')"
            )
        rows = jnp.arange(n, dtype=graph.p_cols.dtype)[:, None]
        # densify the ELL rows; padding entries carry val 0 on the diagonal
        p_dense = jnp.zeros((n, n), y.dtype).at[rows, graph.p_cols].add(graph.p_vals)
        f_attr, kl_attr = exact.exact_attraction(y, p_dense)
        f_rep, z = exact.exact_repulsion(y)
        return combine_forces(f_attr, kl_attr, f_rep, z, exaggeration,
                              graph.p_logp)


@dataclasses.dataclass(frozen=True)
class BarnesHutBackend:
    """The paper's pipeline: Morton encode -> quadtree -> summarize -> traverse."""

    name: ClassVar[str] = "barnes_hut"
    theta: float = 0.5
    depth: int = 16
    compress_tree: bool = True
    use_pallas: bool = False
    attractive_impl: str = DEFAULT_ATTRACTIVE_IMPL
    # row block of the 'blocked' attractive variant — follows
    # TsneConfig.resolve_attractive_block() so the preprocessing chunk_size
    # also bounds the gradient-side gather transients
    attractive_block: int = 512

    def gradient(self, y, graph: NeighborGraph, exaggeration) -> GradResult:
        if self.attractive_impl == "edges" and not graph.has_edges:
            raise ValueError(
                "attractive_impl='edges' but the NeighborGraph carries no edge "
                "list — preprocess with TsneConfig(attractive_impl='edges')"
            )
        if self.attractive_impl != "edges" and graph.p_cols.shape[0] != y.shape[0]:
            raise ValueError(
                f"attractive_impl={self.attractive_impl!r} needs the ELL rows, "
                "but this NeighborGraph was preprocessed edges-only"
            )
        edges = graph.edges if self.attractive_impl == "edges" else None
        return bh_gradient(
            y, graph.p_cols, graph.p_vals, edges,
            self.theta, exaggeration, self.depth, graph.p_logp,
            compress_tree=self.compress_tree, use_pallas=self.use_pallas,
            attractive_impl=self.attractive_impl,
            attractive_block=self.attractive_block,
        )


@dataclasses.dataclass(frozen=True)
class FFTBackend:
    """FIt-SNE-style repulsion: interpolate to a grid, convolve via FFT.

    ``interp_impl`` picks the spread/gather implementation: ``"xla"`` (jnp
    scatter/gather oracles) or ``"pallas"`` (tiled one-hot-matmul kernels,
    interpret-mode on CPU) — see ``core/fft_repulsion.py``.
    """

    name: ClassVar[str] = "fft"
    n_boxes: int = 48
    attractive_impl: str = DEFAULT_ATTRACTIVE_IMPL
    interp_impl: str = "xla"
    attractive_block: int = 512

    def gradient(self, y, graph: NeighborGraph, exaggeration) -> GradResult:
        f_attr, kl_attr = _attractive(y, graph, self.attractive_impl,
                                      self.attractive_block)
        f_rep_unnorm, z = fft_repulsion(y, n_boxes=self.n_boxes,
                                        interp_impl=self.interp_impl)
        return combine_forces(f_attr, kl_attr, f_rep_unnorm, z, exaggeration,
                              graph.p_logp)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

# factory(config, n_points) -> GradientBackend
BackendFactory = Callable[[TsneConfig, int], GradientBackend]

_REGISTRY: dict[str, BackendFactory] = {}


def register_backend(name: str, factory: BackendFactory | None = None):
    """Register a backend factory under ``name``.

    Usable directly — ``register_backend("mine", make_mine)`` — or as a
    decorator::

        @register_backend("mine")
        def make_mine(config: TsneConfig, n: int) -> GradientBackend:
            return MyBackend(...)
    """
    def _register(fn: BackendFactory) -> BackendFactory:
        _REGISTRY[name] = fn
        return fn

    return _register(factory) if factory is not None else _register


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_backend(method: str, config: TsneConfig, n: int) -> GradientBackend:
    """Instantiate the backend registered under ``method`` for an N-point run."""
    try:
        factory = _REGISTRY[method]
    except KeyError:
        raise ValueError(
            f"unknown t-SNE method {method!r}; registered backends: "
            f"{', '.join(available_backends())}"
        ) from None
    return factory(config, n)


@register_backend("exact")
def _make_exact(config: TsneConfig, n: int) -> ExactBackend:
    return ExactBackend()


@register_backend("barnes_hut")
def _make_barnes_hut(config: TsneConfig, n: int) -> BarnesHutBackend:
    return BarnesHutBackend(
        theta=config.theta,
        depth=config.resolve_depth(n),
        compress_tree=config.compress_tree,
        use_pallas=config.use_pallas,
        attractive_impl=config.attractive_impl,
        attractive_block=config.resolve_attractive_block(),
    )


@register_backend("fft")
def _make_fft(config: TsneConfig, n: int) -> FFTBackend:
    return FFTBackend(n_boxes=config.fft_n_boxes,
                      attractive_impl=config.attractive_impl,
                      interp_impl=config.resolve_fft_interp_impl(),
                      attractive_block=config.resolve_attractive_block())
