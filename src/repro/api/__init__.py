"""Public t-SNE surface: sklearn-compatible estimator + backend registry.

    from repro.api import TSNE
    emb = TSNE(method="barnes_hut", perplexity=30).fit_transform(x)

Backends ("exact" | "barnes_hut" | "fft", or your own via
:func:`register_backend`) plug in behind the stable estimator front end.
"""
from repro.core.tsne import (
    GradResult, IterationStats, NeighborGraph, ObserverFn, TsneConfig,
    TsneResult, preprocess, run_tsne,
)
from repro.api.backends import (
    BarnesHutBackend, ExactBackend, FFTBackend, GradientBackend,
    available_backends, make_backend, register_backend, unregister_backend,
)
from repro.api.estimator import TSNE
from repro.neighbors import (
    NeighborBackend, NeighborIndex, available_neighbor_backends,
    build_query_index, make_neighbor_backend, register_neighbor_backend,
    unregister_neighbor_backend,
)
from repro.embed import EmbeddingService, TransformConfig, TransformRequest
from repro.obs import MetricsRegistry, RecompileProbe, Tracer

__all__ = [
    "TSNE",
    "GradientBackend", "ExactBackend", "BarnesHutBackend", "FFTBackend",
    "register_backend", "unregister_backend", "available_backends",
    "make_backend",
    "NeighborBackend", "NeighborIndex", "register_neighbor_backend",
    "unregister_neighbor_backend", "available_neighbor_backends",
    "make_neighbor_backend", "build_query_index",
    "EmbeddingService", "TransformConfig", "TransformRequest",
    "MetricsRegistry", "RecompileProbe", "Tracer",
    "GradResult", "IterationStats", "NeighborGraph", "ObserverFn",
    "TsneConfig", "TsneResult", "preprocess", "run_tsne",
]
