"""scikit-learn-compatible ``TSNE`` estimator over pluggable gradient backends.

Drop-in for ``sklearn.manifold.TSNE`` on the parameters that matter for the
paper's benchmark (261x claim): ``fit`` / ``fit_transform``, ``embedding_``,
``kl_divergence_``, ``n_iter_``, ``learning_rate="auto"`` — with ``method=``
extended beyond sklearn's {"exact", "barnes_hut"} to any name in the backend
registry ("fft" ships in-box), or a :class:`GradientBackend` instance.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Mapping

import numpy as np

from repro import obs
from repro.core.tsne import (
    IterationStats, NeighborGraph, ObserverFn, TsneConfig, TsneResult,
    run_tsne,
)
from repro.api.backends import GradientBackend, make_backend


class TSNE:
    """t-SNE with a pluggable gradient backend.

    Parameters mirror ``sklearn.manifold.TSNE`` (``angle`` is the BH theta;
    ``random_state`` seeds the embedding init).  ``method`` may also be a
    :class:`GradientBackend` instance, which then carries its own settings
    (``angle`` / ``backend_options`` must be left default).  Extras beyond
    sklearn:

    callbacks : iterable of callables receiving :class:`IterationStats`
        every ``kl_every`` iterations (structured observer API).
    kl_every : int
        iteration period for KL evaluation / callbacks / convergence checks.
    backend_options : mapping
        ``TsneConfig`` field overrides for backend construction (e.g.
        ``{"use_pallas": True}``, ``{"compress_tree": False}``,
        ``{"fft_n_boxes": 96}``).  Kernel dispatch flags ride through here
        too: ``{"bsp_impl": "pallas"}`` routes the perplexity search through
        the fused Pallas kernel, ``{"fft_interp_impl": "pallas"}`` the FFT
        backend's spread/gather; both default to ``"auto"`` (follow
        ``use_pallas``).  See docs/KERNELS.md.
    n_neighbors : int or None
        KNN graph degree; ``None`` = sklearn's ``int(3 * perplexity)``.
        Always clamped to ``n_samples - 1``.
    neighbor_method : str
        registered neighbor backend for the KNN stage
        (``"exact"`` | ``"rp_forest"`` | ``"nn_descent"`` | custom).
    neighbor_options : mapping
        constructor options for the neighbor backend (e.g.
        ``{"n_trees": 16}``, ``{"refine_iters": 3}``).
    trace : bool, str or None
        observability switch.  ``None`` (default) defers to the process
        environment (``TSNE_TRACE=1`` enables the global tracer with
        near-zero overhead otherwise); ``True`` records this estimator's
        fits/transforms on a private tracer exposed as ``tracer_`` (with a
        matching ``metrics_`` registry); a string additionally writes a
        Chrome-trace JSON — loadable in Perfetto — to that path after each
        ``fit``.
    """

    def __init__(
        self,
        n_components: int = 2,
        *,
        perplexity: float = 30.0,
        early_exaggeration: float = 12.0,
        learning_rate: float | str = "auto",
        n_iter: int = 1000,
        min_grad_norm: float = 1e-7,
        method: str | GradientBackend = "barnes_hut",
        angle: float = 0.5,
        verbose: int = 0,
        random_state: int | None = None,
        callbacks: Iterable[ObserverFn] = (),
        kl_every: int = 50,
        backend_options: Mapping | None = None,
        n_neighbors: int | None = None,
        neighbor_method: str = "exact",
        neighbor_options: Mapping | None = None,
        trace: bool | str | None = None,
    ):
        self.n_components = n_components
        self.perplexity = perplexity
        self.early_exaggeration = early_exaggeration
        self.learning_rate = learning_rate
        self.n_iter = n_iter
        self.min_grad_norm = min_grad_norm
        self.method = method
        self.angle = angle
        self.verbose = verbose
        self.random_state = random_state
        self.callbacks = tuple(callbacks)
        self.kl_every = kl_every
        self.backend_options = dict(backend_options or {})
        self.n_neighbors = n_neighbors
        self.neighbor_method = neighbor_method
        self.neighbor_options = dict(neighbor_options or {})
        self.trace = trace

    # -- sklearn plumbing ---------------------------------------------------

    def get_params(self, deep: bool = True) -> dict:
        return {
            "n_components": self.n_components,
            "perplexity": self.perplexity,
            "early_exaggeration": self.early_exaggeration,
            "learning_rate": self.learning_rate,
            "n_iter": self.n_iter,
            "min_grad_norm": self.min_grad_norm,
            "method": self.method,
            "angle": self.angle,
            "verbose": self.verbose,
            "random_state": self.random_state,
            "callbacks": self.callbacks,
            "kl_every": self.kl_every,
            "backend_options": self.backend_options,
            "n_neighbors": self.n_neighbors,
            "neighbor_method": self.neighbor_method,
            "neighbor_options": self.neighbor_options,
            "trace": self.trace,
        }

    def set_params(self, **params) -> "TSNE":
        for k, v in params.items():
            if k not in self.get_params():
                raise ValueError(f"invalid parameter {k!r} for TSNE")
            setattr(self, k, v)
        return self

    # -- core ---------------------------------------------------------------

    def _setup_obs(self) -> tuple:
        """Resolve the ``trace`` knob into ``(tracer, metrics)`` for a run.

        ``trace`` falsy: globals (enabled only under ``TSNE_TRACE``) —
        ``tracer_`` / ``metrics_`` point at them when active, else ``None``.
        ``trace`` truthy: a fresh private tracer + registry per fit, kept on
        the estimator so ``transform`` calls append to the same trace.
        """
        if not self.trace:
            g = obs.get_tracer()
            self.tracer_ = g if g.enabled else None
            self.metrics_ = obs.get_metrics() if g.enabled else None
            return None, None            # run_tsne falls back to the globals
        self.tracer_ = obs.Tracer()
        self.metrics_ = obs.MetricsRegistry()
        return self.tracer_, self.metrics_

    def _build_config(self, n: int) -> TsneConfig:
        cfg = TsneConfig(
            perplexity=self.perplexity,
            n_iter=self.n_iter,
            theta=self.angle,
            learning_rate=self.learning_rate,
            early_exaggeration=self.early_exaggeration,
            min_grad_norm=self.min_grad_norm,
            seed=0 if self.random_state is None else int(self.random_state),
            method=self.method if isinstance(self.method, str)
            else getattr(self.method, "name", "barnes_hut"),
            n_neighbors=self.n_neighbors,
            neighbor_method=self.neighbor_method,
            neighbor_options=self.neighbor_options or None,
        )
        if self.backend_options:
            cfg = dataclasses.replace(cfg, **self.backend_options)
        return cfg

    def fit(self, x, y=None) -> "TSNE":
        """Fit x [n_samples, n_features] into the embedding space."""
        x = np.asarray(x, np.float32)
        if x.ndim != 2:
            raise ValueError(f"expected 2-D input, got shape {x.shape}")
        n = x.shape[0]
        if self.n_components != 2:
            raise ValueError(
                "this implementation embeds into 2 dimensions only "
                f"(n_components={self.n_components})"
            )
        if n <= 3 * self.perplexity:
            raise ValueError(
                f"perplexity {self.perplexity} is too large for n_samples={n} "
                "(need n_samples > 3 * perplexity)"
            )
        config = self._build_config(n)

        if isinstance(self.method, str):
            backend = make_backend(self.method, config, n)
        elif isinstance(self.method, GradientBackend):
            # an instance carries its own settings (theta, grid size, ...);
            # refuse silently-ignored estimator-level overrides
            if self.backend_options:
                raise ValueError(
                    "backend_options have no effect when method= is a "
                    "GradientBackend instance — set them on the instance"
                )
            if self.angle != 0.5 and hasattr(self.method, "theta"):
                raise ValueError(
                    "angle= has no effect when method= is a GradientBackend "
                    "instance — set theta on the instance"
                )
            backend = self.method
        else:
            raise TypeError(
                f"method must be a registered backend name or a GradientBackend "
                f"instance, got {type(self.method).__name__}"
            )

        observers = list(self.callbacks)
        if self.verbose:
            observers.append(
                lambda s: print(
                    f"[t-SNE:{backend.name}] iter {s.iteration:5d}  "
                    f"KL {s.kl:.4f}  |grad| {s.grad_norm:.2e}  {s.elapsed_s:.1f}s"
                )
            )

        def observer(stats: IterationStats) -> None:
            for fn in observers:
                fn(stats)

        tracer, metrics = self._setup_obs()
        result: TsneResult = run_tsne(
            x, config,
            observer=observer if observers else None,
            kl_every=self.kl_every,
            backend=backend,
            tracer=tracer,
            metrics=metrics,
        )
        if isinstance(self.trace, str) and tracer is not None:
            tracer.to_chrome_trace(self.trace, process_name="tsne.fit")
        self.embedding_ = result.y
        self.kl_divergence_ = result.kl
        self.kl_history_ = result.kl_history
        self.n_iter_ = result.n_iter
        self.learning_rate_ = config.resolve_lr(n)
        self.timings_ = result.timings
        self.n_features_in_ = x.shape[1]
        self.neighbor_graph_ = result.graph
        self.n_neighbors_ = config.resolve_n_neighbors(n)
        self._x_fit = x
        self._query_index = None            # built lazily on first transform
        return self

    def fit_transform(self, x, y=None) -> np.ndarray:
        """Fit x and return the [n_samples, 2] embedding."""
        self.fit(x, y)
        return self.embedding_

    # -- out-of-sample ------------------------------------------------------

    def _check_fitted(self) -> None:
        if getattr(self, "embedding_", None) is None:
            raise ValueError("this TSNE instance is not fitted yet — call "
                             "fit / fit_transform (or TSNE.load) first")

    @property
    def query_index_(self):
        """Neighbor-backend query index over the fitted inputs (lazy).

        Built by the same backend that built the fit-time KNN graph
        (``rp_forest`` reuses its forest; backends without a query path fall
        back to exact), then cached until the next ``fit``.
        """
        self._check_fitted()
        if getattr(self, "_query_index", None) is None:
            from repro.neighbors import build_query_index, make_neighbor_backend
            config = self._build_config(self._x_fit.shape[0])
            backend = make_neighbor_backend(
                config.neighbor_method, config.resolve_neighbor_options()
            )
            self._query_index = build_query_index(backend, self._x_fit)
        return self._query_index

    @property
    def query_k_(self) -> int:
        """Neighbor width for out-of-sample queries (the fit-time k)."""
        self._check_fitted()
        return int(self.n_neighbors_)

    def transform(self, x_new, *, transform_config=None,
                  return_stats: bool = False):
        """Embed new points into the *frozen* fitted embedding — no refit.

        Each row of ``x_new [M, n_features]`` finds its ``query_k_`` nearest
        fitted inputs through the fitted neighbor structure, receives
        perplexity-calibrated similarities over them, and descends
        (attractive-only, momentum + gains, per-point early stop) against
        their frozen embedding coordinates, starting from their p-weighted
        mean.  Fixed-shape jitted step: batches of any size share one trace.

        Returns ``y [M, 2]`` (and per-point ``TransformStats`` when
        ``return_stats=True``).
        """
        from repro.embed.transform import TransformConfig, transform_batch

        self._check_fitted()
        x_new = np.asarray(x_new, np.float32)
        if x_new.ndim != 2 or x_new.shape[1] != self.n_features_in_:
            raise ValueError(
                f"expected x_new shaped [m, {self.n_features_in_}], got "
                f"{x_new.shape}"
            )
        cfg = transform_config or TransformConfig()
        perp = cfg.perplexity if cfg.perplexity is not None else self.perplexity
        y, stats = transform_batch(
            x_new, self.query_index_, self.embedding_,
            k=self.query_k_, perplexity=float(perp), config=cfg,
            tracer=getattr(self, "tracer_", None),
        )
        return (y, stats) if return_stats else y

    # -- persistence --------------------------------------------------------

    _SAVE_SCHEMA = 1

    def save(self, path) -> None:
        """Persist the fitted state (npz): embedding, fitted inputs, sparse-P
        neighbor graph, and constructor params — enough for ``load`` to serve
        ``transform`` queries in another process without refitting."""
        self._check_fitted()
        params = self.get_params()
        params.pop("callbacks", None)       # not serializable, fit-only
        if not isinstance(params["method"], str):
            params["method"] = getattr(params["method"], "name", "barnes_hut")
        arrays = dict(
            schema=np.int32(self._SAVE_SCHEMA),
            embedding=np.asarray(self.embedding_, np.float32),
            x_fit=np.asarray(self._x_fit, np.float32),
            kl_divergence=np.float64(self.kl_divergence_),
            kl_history=np.asarray(self.kl_history_, np.float64),
            n_iter_run=np.int32(self.n_iter_),
            learning_rate=np.float64(self.learning_rate_),
            n_neighbors_fit=np.int32(self.n_neighbors_),
            params_json=np.array(json.dumps(params)),
        )
        g = getattr(self, "neighbor_graph_", None)
        if g is not None:
            arrays.update(
                graph_p_cols=np.asarray(g.p_cols, np.int32),
                graph_p_vals=np.asarray(g.p_vals, np.float32),
                graph_edge_src=np.asarray(g.edge_src, np.int32),
                graph_edge_dst=np.asarray(g.edge_dst, np.int32),
                graph_edge_w=np.asarray(g.edge_w, np.float32),
                graph_p_logp=np.float64(g.p_logp),
                graph_has_edges=np.bool_(g.has_edges),
            )
        np.savez_compressed(path, **arrays)

    @classmethod
    def load(cls, path) -> "TSNE":
        """Rebuild a fitted estimator persisted with :meth:`save`; the query
        index is rebuilt lazily on the first ``transform``.

        ``timings_`` is ``None`` on a loaded model: no phases ran in this
        process, so there is nothing to report — distinct from the populated
        dict a real ``fit`` leaves behind.  (``{}`` would be indistinguishable
        from a fitted-but-untimed model.)"""
        z = np.load(path, allow_pickle=False)
        if int(z["schema"]) != cls._SAVE_SCHEMA:
            raise ValueError(
                f"unsupported TSNE save schema {int(z['schema'])} "
                f"(expected {cls._SAVE_SCHEMA})"
            )
        params = json.loads(str(z["params_json"]))
        est = cls(**params)
        est.embedding_ = np.asarray(z["embedding"])
        est._x_fit = np.asarray(z["x_fit"])
        est.kl_divergence_ = float(z["kl_divergence"])
        est.kl_history_ = np.asarray(z["kl_history"])
        est.n_iter_ = int(z["n_iter_run"])
        est.learning_rate_ = float(z["learning_rate"])
        est.n_neighbors_ = int(z["n_neighbors_fit"])
        est.n_features_in_ = est._x_fit.shape[1]
        est.timings_ = None         # loaded, not fitted here: no phase ran
        est._query_index = None
        if "graph_p_cols" in z.files:
            est.neighbor_graph_ = NeighborGraph(
                p_cols=z["graph_p_cols"], p_vals=z["graph_p_vals"],
                edge_src=z["graph_edge_src"], edge_dst=z["graph_edge_dst"],
                edge_w=z["graph_edge_w"], p_logp=float(z["graph_p_logp"]),
                n=est._x_fit.shape[0], has_edges=bool(z["graph_has_edges"]),
            )
        else:
            est.neighbor_graph_ = None
        return est
