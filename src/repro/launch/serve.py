"""Serving launcher (reduced configs on CPU; decode-shape cells at pod
scale are exercised by launch/dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_3b --requests 6
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max_new", type=int, default=8)
    args = ap.parse_args()

    import jax
    from repro.configs import get_reduced_config
    from repro.models.model import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = get_reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, batch_slots=args.slots, max_seq=96)
    for r in range(args.requests):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new_tokens=args.max_new))
    done = eng.run(params)
    print(f"served {len(done)} requests "
          f"({sum(len(r.generated) for r in done)} tokens)")


if __name__ == "__main__":
    main()
