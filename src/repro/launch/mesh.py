"""Production mesh definitions.

Functions (not module constants) so importing never touches jax device
state; the dry-run forces 512 host devices *before* calling these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_dp_size(mesh) -> int:
    size = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            size *= mesh.shape[ax]
    return size


def mesh_tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)
