"""Build one (architecture x shape x mesh) dry-run cell: the jitted,
sharded step function + abstract operand shapes, ready to lower."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import (
    DEFAULT_RULES, MeshRules, params_shardings, use_mesh_rules,
)
from repro.launch.mesh import mesh_dp_size, mesh_tp_size
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.step import make_opt_init, make_train_step, opt_config_for


def cell_rules(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """Per-cell adjustments of the logical->physical axis rules.

    Resolves the cache sharding policy so no spec uses a mesh axis twice:
      batch shardable  -> cache: (batch over data, heads over model if they
                          divide, else sequence over model)
      batch unshardable (long_500k) -> SP: sequence over data (+ heads over
                          model when divisible)
    """
    rules = dict(DEFAULT_RULES)
    dp = mesh_dp_size(mesh)
    tp = mesh_tp_size(mesh)
    batch_ok = shape.global_batch % dp == 0
    heads_ok = cfg.n_kv_heads % tp == 0
    # Megatron-style sequence parallelism for the residual stream: the
    # layer-scan carry (saved for backward) is sharded over the model axis,
    # cutting saved activations by TP; projections re-gather as needed.
    if shape.kind in ("train", "prefill") and shape.seq_len % tp == 0:
        rules["seq"] = "model"
    if batch_ok:
        rules["kv_seq"] = None if heads_ok else "model"
    else:
        rules["batch"] = None
        rules["kv_seq"] = "data"
    if not heads_ok:
        rules["kv_heads"] = None
    # MLA latent caches have no head dim: always sequence-shard over model
    # when batch takes the data axes
    rules["latent_seq"] = ("model" if batch_ok else "data")
    return rules


def cache_shardings(cache, cfg: ArchConfig, shape: ShapeConfig, mesh, rules: dict):
    """NamedSharding pytree for a decode cache (reads the resolved rules)."""
    mr = MeshRules(mesh, rules)
    b_ax = mr.axis("batch")
    seq_ax = mr.axis("kv_seq")
    h_ax = mr.axis("kv_heads")
    lat_ax = mr.axis("latent_seq")

    def fits(shape_, spec):
        return all(d % _ax_size(mesh, a) == 0 for d, a in zip(shape_, spec))

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        nd = leaf.ndim
        spec = [None] * nd
        if (pstr.endswith("k") or pstr.endswith("v")) and nd == 5:
            spec = [None, b_ax, seq_ax, h_ax, None]   # [L, B, S, Hkv, dh]
        elif ("ckv" in pstr or "krope" in pstr) and nd == 4:
            spec = [None, b_ax, lat_ax, None]          # [L, B, S, r]
        elif nd >= 2:
            spec[1] = b_ax                              # states, conv, x_time
        spec = [a if leaf.shape[i] % _ax_size(mesh, a) == 0 else None
                for i, a in enumerate(spec)]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(visit, cache)


def _ax_size(mesh, ax):
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def batch_shardings(specs: dict, mesh, rules: dict):
    mr = MeshRules(mesh, rules)
    b_ax = mr.axis("batch")

    def one(name, s):
        spec = [None] * len(s.shape)
        if len(s.shape) >= 1:
            spec[0] = b_ax
        return NamedSharding(mesh, P(*spec))

    return {k: one(k, v) for k, v in specs.items()}


def build_cell(arch: str, shape_name: str, mesh, opt_cfg: AdamWConfig | None = None):
    """Returns (lowered, info) — `lowered` is the jax Lowered for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        raise ValueError(f"{arch} skips {shape_name}: {cfg.skip_reason}")
    model = build_model(cfg)
    rules = cell_rules(cfg, shape, mesh)
    opt_cfg = opt_cfg or opt_config_for(cfg)

    with use_mesh_rules(mesh, rules):
        key = jax.random.PRNGKey(0)
        param_shapes = jax.eval_shape(model.init, key)
        p_sh = params_shardings(param_shapes, mesh, rules)
        specs = model.input_specs(shape)

        if shape.kind == "train":
            train_step = make_train_step(model, opt_cfg, grad_shardings=p_sh)
            opt_init = make_opt_init(model, opt_cfg)
            opt_shapes = jax.eval_shape(opt_init, param_shapes)
            # moments share the param tree sharding; step counter replicated
            o_sh = type(opt_shapes)(
                step=NamedSharding(mesh, P()),
                m=params_shardings(opt_shapes.m, mesh, rules),
                v=params_shardings(opt_shapes.v, mesh, rules),
            )
            b_sh = batch_shardings(specs, mesh, rules)
            # AOT path: the jit wrapper is lowered immediately and discarded
            # — one compile per build_cell call by construction, no cache.
            fn = jax.jit(  # repro-lint: disable=RT102
                train_step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(param_shapes, opt_shapes, specs)
        elif shape.kind == "prefill":
            b_sh = batch_shardings(specs, mesh, rules)
            # repro-lint: disable=RT102 — AOT lower-and-discard, see above
            fn = jax.jit(model.prefill, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(param_shapes, specs)
        else:  # decode
            c_sh = cache_shardings(specs["cache"], cfg, shape, mesh, rules)
            tok_sh = batch_shardings(
                {"token": specs["token"], "pos": specs["pos"]}, mesh, rules)
            # repro-lint: disable=RT102 — AOT lower-and-discard, see above
            fn = jax.jit(
                model.decode_step,
                in_shardings=(p_sh, c_sh, tok_sh["token"], tok_sh["pos"]),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = fn.lower(param_shapes, specs["cache"], specs["token"], specs["pos"])

    info = dict(arch=arch, shape=shape_name, kind=shape.kind,
                mesh_shape=dict(mesh.shape), n_devices=mesh.size)
    return lowered, info
