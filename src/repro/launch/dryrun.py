import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x shape) cell on
the production meshes and record memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_405b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi_pod] [--out runs/dryrun]

The 512 placeholder host devices exist ONLY here (the env var above must be
set before any jax import); smoke tests and benchmarks see 1 device.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, param_count
from repro.launch.cell import build_cell
from repro.launch.hlo_analysis import collective_bytes, model_flops, roofline_terms
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             save_hlo: bool = False) -> dict:
    mesh_tag = "pod512" if multi_pod else "pod256"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    out_path = out_dir / f"{tag}.json"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = dict(arch=arch, shape=shape_name, mesh=mesh_tag, ok=False)

    if shape_name in cfg.skip_shapes:
        rec.update(skipped=True, reason=cfg.skip_reason, ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, info = build_cell(arch, shape_name, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = {}
        try:
            ma = compiled.memory_analysis()
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
        except Exception as e:  # noqa: BLE001 - CPU backend may not implement
            mem["error"] = str(e)

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            for k in ("flops", "bytes accessed", "optimal_seconds", "utilization operand"):
                if ca and k in ca:
                    cost[k] = float(ca[k])
            if ca:
                cost.update({k: float(v) for k, v in ca.items()
                             if isinstance(v, (int, float)) and len(cost) < 24})
        except Exception as e:  # noqa: BLE001
            cost["error"] = str(e)

        hlo = compiled.as_text()
        coll_once = collective_bytes(hlo)       # body-once (XLA-style) counts
        if save_hlo:
            (out_dir / f"{tag}.hlo.txt").write_text(hlo)

        # loop-trip-aware cost model (XLA cost_analysis counts scan bodies
        # once; see launch/hlo_cost.py) — these drive the roofline terms
        hc = analyze_hlo(hlo)
        coll = hc["collectives"]
        n_chips = mesh.size
        flops_dev = hc["flops"]
        bytes_dev = hc["bytes"]
        roof = roofline_terms(flops_dev, bytes_dev, float(coll["total"]), n_chips)
        roof["xla_flops_body_once"] = cost.get("flops", 0.0)
        roof["xla_bytes_body_once"] = cost.get("bytes accessed", 0.0)
        roof["collectives_body_once"] = coll_once
        total_p, active_p = param_count(cfg)
        mf = model_flops(cfg, shape, active_p)
        roof["model_flops"] = mf
        roof["useful_fraction"] = mf / roof["hlo_flops_global"] if roof["hlo_flops_global"] else None

        rec.update(
            ok=True, skipped=False, lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            n_devices=n_chips, memory=mem, cost_per_device=cost,
            collectives_per_device=coll, roofline=roof,
            params_total=total_p, params_active=active_p,
            hlo_bytes=len(hlo),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--both_meshes", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save_hlo", action="store_true")
    ap.add_argument("--skip_existing", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod512' if mp else 'pod256'}"
                out_path = out_dir / f"{tag}.json"
                if args.skip_existing and out_path.exists():
                    prev = json.loads(out_path.read_text())
                    if prev.get("ok"):
                        print(f"[skip] {tag}")
                        continue
                t0 = time.time()
                rec = run_cell(arch, shape, mp, out_dir, save_hlo=args.save_hlo)
                dt = time.time() - t0
                if rec["ok"]:
                    n_ok += 1
                    status = "SKIP " + rec.get("reason", "")[:40] if rec.get("skipped") else "OK"
                    mem = rec.get("memory", {})
                    arg_gb = mem.get("argument_size_in_bytes", 0) / 2**30
                    tmp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
                    dom = rec.get("roofline", {}).get("dominant", "-")
                    print(f"[{status:>5}] {tag}  {dt:6.1f}s args={arg_gb:.2f}GiB "
                          f"temp={tmp_gb:.2f}GiB bound={dom}", flush=True)
                else:
                    n_fail += 1
                    print(f"[FAIL ] {tag}  {dt:6.1f}s {rec['error'][:200]}", flush=True)
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
