"""HLO-text cost model with loop-trip-count awareness.

XLA's built-in ``compiled.cost_analysis()`` counts each while-loop body
*once*, so any scan-over-layers / microbatch / flash-chunk model is
undercounted by orders of magnitude.  This analyzer parses the post-
optimization HLO text and:

  * multiplies while bodies by their static trip count (read from the
    ``s32[] constant(N)`` in the loop condition — scans/fori always lower
    to such a bound);
  * counts FLOPs from dot shapes (2*M*N*K with batch/contracting dims from
    the printed dnums) plus 1 flop/element for arithmetic elementwise ops,
    recursing into fusion bodies;
  * models HBM bytes as sum(operand + result bytes) of *top-level* ops only
    (fusion internals are register/VMEM-resident post-fusion);
  * buckets collective bytes (result shapes; '-done' halves skipped).

Used by launch/dryrun.py for the roofline terms (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops counted at 1 flop per output element
_ELTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "log-plus-one", "exponential-minus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sine", "cosine", "logistic",
    "compare", "select", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "atan2", "erf",
    "remainder", "clamp", "cbrt",
}
_REDUCE_OPS = {"reduce", "reduce-window"}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) summed over all array shapes in a type string."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str
    is_root: bool = False


def _canon(type_str: str) -> str:
    """dims+dtype only (layout annotations stripped)."""
    return re.sub(r"\{[^}]*\}", "", type_str)


@dataclasses.dataclass
class Computation:
    name: str
    param_types: dict[str, str]
    instrs: list[Instr]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)$")
_INSTR_HEAD = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _take_balanced(s: str, open_ch="(", close_ch=")") -> tuple[str, str]:
    """s starts with open_ch; return (group incl parens, remainder)."""
    depth = 0
    for i, ch in enumerate(s):
        if ch == open_ch:
            depth += 1
        elif ch == close_ch:
            depth -= 1
            if depth == 0:
                return s[: i + 1], s[i + 1:]
    return s, ""


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        if not line.startswith(" ") and ("{" in line) and ("(" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                name = m.group(1)
                # parameter list: "pname: type, pname: type) -> ..."
                params = {}
                sig = m.group(2)
                for pm in re.finditer(r"([\w.\-]+):\s*(\([^)]*\)|[^,)]+)", sig):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(name=name, param_types=params, instrs=[])
                comps[name] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_HEAD.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # result type: a balanced tuple "(... /*index=5*/ ...)" or one token
        if rest.startswith("("):
            type_str, after = _take_balanced(rest)
        else:
            parts = rest.split(" ", 1)
            type_str, after = parts[0], (parts[1] if len(parts) > 1 else "")
        after = after.strip()
        paren = after.find("(")
        if paren < 0:
            continue
        opcode = after[:paren].strip()
        args, attrs = _take_balanced(after[paren:])
        operands = _OPERAND_RE.findall(args)
        cur.instrs.append(Instr(name=name, type_str=type_str, opcode=opcode,
                                operands=operands, attrs=attrs, line=line,
                                is_root="ROOT" in line.split("=")[0]))
    return comps


def _fusion_bytes(ins: Instr, types: dict[str, str], comps: dict) -> float:
    """Precise HBM traffic of a fusion op.

    * output: if the fusion root is a dynamic-update-slice the destination
      is updated in place — write = update-slice bytes, not the buffer;
    * inputs: a parameter whose only body consumers are dynamic-slices is
      read slice-wise (sum of slice outputs); a parameter that is the
      in-place destination of a root DUS is not read at all; anything else
      is a full read.
    """
    m = re.search(r"calls=%([\w.\-]+)", ins.line)
    body = comps.get(m.group(1)) if m else None
    _, out_b = _shape_elems_bytes(ins.type_str)
    if body is None:
        in_b = sum(_shape_elems_bytes(types[o])[1] for o in ins.operands if o in types)
        return out_b + in_b
    body_types = dict(body.param_types)
    params_in_order: list[str] = []
    for bi in body.instrs:
        body_types[bi.name] = bi.type_str
        if bi.opcode == "parameter":
            params_in_order.append(bi.name)
    root = next((bi for bi in body.instrs if bi.is_root), body.instrs[-1] if body.instrs else None)
    dus_dest = None
    if root is not None and root.opcode == "dynamic-update-slice":
        dus_dest = root.operands[0] if root.operands else None
        upd = root.operands[1] if len(root.operands) > 1 else None
        out_b = _shape_elems_bytes(body_types.get(upd, ""))[1] * 2 if upd else out_b

    def param_read(pname: str) -> float:
        full = _shape_elems_bytes(body_types.get(pname, ""))[1]
        consumers = [bi for bi in body.instrs if pname in bi.operands]
        if not consumers:
            return 0.0
        if any(bi.opcode == "dynamic-update-slice" and bi.operands
               and bi.operands[0] == pname for bi in consumers):
            return 0.0                                     # in-place dest
        if all(bi.opcode in ("dynamic-slice", "gather") for bi in consumers):
            return float(sum(_shape_elems_bytes(bi.type_str)[1] for bi in consumers))
        return float(full)

    in_b = 0.0
    for op_name, pname in zip(ins.operands, params_in_order):
        in_b += param_read(pname)
    return out_b + in_b


def _trip_count(cond: Computation) -> int:
    """largest s32[] scalar constant in the loop condition = loop bound."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.type_str.startswith("s32[]"):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return max(best, 1)


_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w.\-,% ]+)\}?")


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    out_elems = 1
    for d in _dims_of(ins.type_str):
        out_elems *= d
    lhs = ins.operands[0] if ins.operands else None
    k = 1
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if lhs is not None and lhs in types and cdims:
        dims = _dims_of(types[lhs])
        for i in cdims.group(1).split(","):
            if i and int(i) < len(dims):
                k *= dims[int(i)]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> dict:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip()[6:].strip())
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the computation named like the module, else largest
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    memo_flops: dict[str, float] = {}
    memo_bytes: dict[str, float] = {}
    memo_coll: dict[str, dict] = {}

    def flops_of(cname: str) -> float:
        if cname in memo_flops:
            return memo_flops[cname]
        memo_flops[cname] = 0.0  # cycle guard
        comp = comps.get(cname)
        if comp is None:
            return 0.0
        total = 0.0
        types = dict(comp.param_types)
        for ins in comp.instrs:
            types[ins.name] = ins.type_str
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                total += _dot_flops(ins, types)
            elif op in _ELTWISE:
                total += _shape_elems_bytes(ins.type_str)[0]
            elif op in _REDUCE_OPS:
                # ~1 flop per input element
                for o in ins.operands[: max(1, len(ins.operands) // 2)]:
                    if o in types:
                        total += _shape_elems_bytes(types[o])[0]
            elif op == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", ins.line)
                if m:
                    total += flops_of(m.group(1))
            elif op == "while":
                mb = re.search(r"body=%([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%([\w.\-]+)", ins.line)
                trip = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb:
                    total += trip * flops_of(mb.group(1))
            elif op in ("call", "custom-call", "async-start"):
                m = re.search(r"(?:to_apply|calls|called_computation)=%([\w.\-]+)", ins.line)
                if m:
                    total += flops_of(m.group(1))
            elif op == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if mbr:
                    branches = [_b.strip().lstrip("%") for _b in mbr.group(1).split(",")]
                    vals = [flops_of(b) for b in branches if b in comps]
                    if vals:
                        total += max(vals)
        memo_flops[cname] = total
        return total

    def bytes_of(cname: str) -> float:
        if cname in memo_bytes:
            return memo_bytes[cname]
        memo_bytes[cname] = 0.0
        comp = comps.get(cname)
        if comp is None:
            return 0.0
        total = 0.0
        types = dict(comp.param_types)
        for ins in comp.instrs:
            types[ins.name] = ins.type_str
        for ins in comp.instrs:
            op = ins.opcode
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            if op == "while":
                mb = re.search(r"body=%([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%([\w.\-]+)", ins.line)
                trip = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb:
                    total += trip * bytes_of(mb.group(1))
                if mc and mc.group(1) in comps:
                    total += trip * bytes_of(mc.group(1))
                continue
            if op == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if mbr:
                    branches = [_b.strip().lstrip("%") for _b in mbr.group(1).split(",")]
                    vals = [bytes_of(b) for b in branches if b in comps]
                    if vals:
                        total += max(vals)
                continue
            if op == "call":
                m = re.search(r"to_apply=%([\w.\-]+)", ins.line)
                if m:
                    total += bytes_of(m.group(1))
                continue
            # top-level op: operand + result traffic
            _, out_b = _shape_elems_bytes(ins.type_str)
            if op == "fusion":
                total += _fusion_bytes(ins, types, comps)
                continue
            if op == "dynamic-update-slice":
                # in-place update: traffic = update slice read + region write
                upd = sum(_shape_elems_bytes(types[o])[1] for o in ins.operands
                          if o in types and _canon(types[o]) != _canon(ins.type_str))
                total += 2 * max(upd, 1)
                continue
            if op == "dynamic-slice":
                total += 2 * out_b
                continue
            in_b = sum(_shape_elems_bytes(types[o])[1] for o in ins.operands if o in types)
            total += out_b + in_b
        memo_bytes[cname] = total
        return total

    def coll_of(cname: str) -> dict:
        if cname in memo_coll:
            return memo_coll[cname]
        memo_coll[cname] = defaultdict(float)
        comp = comps.get(cname)
        if comp is None:
            return {}
        acc: dict[str, float] = defaultdict(float)
        for ins in comp.instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                acc[base] += _shape_elems_bytes(ins.type_str)[1]
            elif op == "while":
                mb = re.search(r"body=%([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%([\w.\-]+)", ins.line)
                trip = _trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb:
                    for kk, vv in coll_of(mb.group(1)).items():
                        acc[kk] += trip * vv
            elif op == "fusion":
                pass  # collectives never fuse
            elif op in ("call", "conditional"):
                for m in re.finditer(r"%([\w.\-]+)", ins.attrs.split(")", 1)[-1]):
                    if m.group(1) in comps:
                        for kk, vv in coll_of(m.group(1)).items():
                            acc[kk] += vv
        memo_coll[cname] = acc
        return acc

    coll = dict(coll_of(entry))
    for kind in _COLLECTIVES:
        coll.setdefault(kind, 0.0)
    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    return {
        "flops": flops_of(entry),
        "bytes": bytes_of(entry),
        "collectives": coll,
        "entry": entry,
        "n_computations": len(comps),
    }
