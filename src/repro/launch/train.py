"""Pod-scale training launcher: wires an assigned architecture, the mesh,
sharded train_step and the fault-tolerant Trainer together.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek_7b \
        --data_parallel 2 --model_parallel 1 --steps 20 --reduced

On real hardware the same entry point runs per host under
``jax.distributed.initialize()`` (multi-controller); device counts and the
mesh shape come from flags.  With --reduced it runs the smoke-scale config
on whatever devices exist (CPU included).
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data_parallel", type=int, default=1)
    ap.add_argument("--model_parallel", type=int, default=1)
    ap.add_argument("--ckpt_dir", default="runs/launch_train")
    ap.add_argument("--compress_grads", action="store_true")
    args = ap.parse_args()

    import jax
    from repro.configs import get_config, get_reduced_config
    from repro.data.pipeline import FrontendPipeline, TokenPipeline
    from repro.distributed.sharding import DEFAULT_RULES, use_mesh_rules
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import build_model
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    if cfg.family == "vlm":
        pipe = FrontendPipeline(cfg.vocab_size, args.batch, args.seq, seed=0,
                                frontend_key="patches",
                                frontend_shape=(cfg.vlm.n_patches, cfg.d_model))
    elif cfg.family == "audio":
        pipe = FrontendPipeline(cfg.vocab_size, args.batch, args.seq, seed=0,
                                frontend_key="frames",
                                frontend_shape=(cfg.enc_dec.n_frames, cfg.d_model))
    else:
        pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq, seed=0)

    tcfg = TrainerConfig(n_steps=args.steps, ckpt_every=max(args.steps // 2, 5),
                         ckpt_dir=args.ckpt_dir, log_every=5)
    n_dev = args.data_parallel * args.model_parallel
    if n_dev > 1:
        mesh = make_local_mesh(args.data_parallel, args.model_parallel)
        with use_mesh_rules(mesh, DEFAULT_RULES):
            trainer = Trainer(model, pipe, tcfg)
            trainer.run(callback=lambda s, m: print(f"step {s} loss {m['loss_mean']:.4f}"))
    else:
        trainer = Trainer(model, pipe, tcfg)
        trainer.run(callback=lambda s, m: print(f"step {s} loss {m['loss_mean']:.4f}"))


if __name__ == "__main__":
    main()
