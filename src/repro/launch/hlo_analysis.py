"""Roofline-term extraction from compiled dry-run artifacts.

collective_bytes is not in cost_analysis(): we parse the (post-SPMD,
per-device) HLO text and sum result-shape bytes of every collective op,
bucketed by op kind.  Hardware model: TPU v5e — 197 TFLOP/s bf16/chip,
819 GB/s HBM/chip, ~50 GB/s/link ICI (spec-provided constants).
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type (possibly a tuple) followed by the collective opcode
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes moved through each collective kind (result shapes);
    '-done' ops are skipped so async pairs are not double counted."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, n_chips: int) -> dict[str, Any]:
    """Three roofline terms in seconds (global quantities / aggregate rate
    == per-device quantity / per-chip rate for uniformly sharded work)."""
    t_compute = flops_per_dev / PEAK_FLOPS
    t_memory = bytes_per_dev / HBM_BW
    t_coll = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_time_s": total,
        "hlo_flops_global": flops_per_dev * n_chips,
        "hlo_bytes_global": bytes_per_dev * n_chips,
        "collective_bytes_global": coll_bytes_per_dev * n_chips,
    }


def model_flops(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D for train, 2 * N_active * D for
    inference-style steps (D = tokens processed by the step)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active_params * tokens
    tokens = shape.global_batch                      # one token per sequence
    return 2.0 * active_params * tokens
