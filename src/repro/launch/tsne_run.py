"""t-SNE launcher — single-device (estimator API) or sharded runs.

    PYTHONPATH=src python -m repro.launch.tsne_run --dataset digits --n 1797
    PYTHONPATH=src python -m repro.launch.tsne_run --method fft --n 4096
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.tsne_run --dataset mnist --n 4096 --devices 8
"""
from __future__ import annotations

import argparse
import functools

import jax


@functools.partial(jax.jit, static_argnames=(
    "mesh", "theta", "exag", "mom", "lr", "min_gain"))
def _dist_step(state, cols, vals, *, mesh, theta, exag, mom, lr, min_gain):
    """One sharded GD step.  Module-level so the compile cache is shared
    across iterations; ``cols``/``vals`` are operands (not closure
    captures baked into the jaxpr as constants).  (exag, mom) take two
    values each over a run — at most 4 traces."""
    from repro.core.distributed import distributed_bh_gradient
    from repro.core.tsne import gd_update

    res = distributed_bh_gradient(mesh, state.y, cols, vals, 0.0,
                                  theta=theta, exaggeration=exag)
    return gd_update(state, res.grad, lr, mom, min_gain), res.kl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="digits")
    ap.add_argument("--n", type=int, default=1797)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--perplexity", type=float, default=30.0)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--method", default="barnes_hut",
                    help="gradient backend: exact | barnes_hut | fft | any registered name")
    ap.add_argument("--devices", type=int, default=1,
                    help=">1: shard points over a data mesh (distributed step)")
    ap.add_argument("--out", default="tsne_out.npy")
    args = ap.parse_args()

    import jax.numpy as jnp
    import numpy as np
    from repro.api import TSNE
    from repro.core import bsp
    from repro.core.knn import knn
    from repro.core.similarity import symmetrize_ell
    from repro.core.tsne import TsneConfig, init_state
    from repro.data.datasets import make_dataset

    x, _ = make_dataset(args.dataset, n=args.n)
    cfg = TsneConfig(perplexity=args.perplexity, theta=args.theta, n_iter=args.iters)

    if args.devices <= 1:
        est = TSNE(method=args.method, perplexity=args.perplexity,
                   angle=args.theta, n_iter=args.iters, verbose=1)
        emb = est.fit_transform(x)
        np.save(args.out, emb)
        print(f"KL={est.kl_divergence_:.4f} n_iter={est.n_iter_} -> {args.out}")
        return

    # distributed path: points sharded over a 1-D data mesh
    from repro.core.distributed import ring_knn
    mesh = jax.make_mesh((args.devices,), ("data",))
    n = args.n - args.n % args.devices
    x = jnp.asarray(x[:n])
    k = cfg.resolve_n_neighbors(n)
    idx, d2 = ring_knn(mesh, x, k)
    cond_p, _ = bsp.binary_search_perplexity(d2, cfg.perplexity)
    cols, vals = symmetrize_ell(np.asarray(idx), np.asarray(cond_p))
    cols = jnp.asarray(cols)
    vals = jnp.asarray(vals, jnp.float32)
    state = init_state(n, cfg)
    lr = cfg.resolve_lr(n)

    for it in range(args.iters):
        exag = cfg.early_exaggeration if it < cfg.exaggeration_iters else 1.0
        mom = cfg.momentum_initial if it < cfg.momentum_switch_iter else cfg.momentum_final
        state, kl = _dist_step(state, cols, vals, mesh=mesh, theta=cfg.theta,
                               exag=exag, mom=mom, lr=lr,
                               min_gain=cfg.min_gain)
        if (it + 1) % 100 == 0:
            print(f"iter {it+1} KL {float(kl):.4f}")
    np.save(args.out, np.asarray(state.y))
    print(f"distributed run done -> {args.out}")


if __name__ == "__main__":
    main()
