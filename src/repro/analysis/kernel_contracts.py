"""Static contract checker for the Pallas kernel registry (codes KC2xx).

The parity tests in ``tests/test_kernels.py`` prove the kernels *compute*
the right thing at the shapes they run; this pass proves the **BlockSpec
geometry** is right at the shapes the config *permits* — the difference
between "worked on digits" and "won't silently overflow VMEM at
N=1.3M, K=1024".

Mechanism: every registry entry (``kernels.ops.kernel_registry()``) is
traced with ``jax.eval_shape`` — no FLOP executes — under a temporarily
wrapped ``pl.pallas_call`` that records each call's ``grid`` /
``in_specs`` / ``out_specs`` / ``out_shape`` together with the concrete
operand shapes.  Sample operands are built at the *declared envelope*:
the maximum neighbor width :data:`repro.core.tsne.MAX_N_NEIGHBORS` and
FFT lattice :data:`repro.core.fft_repulsion.MAX_N_BOXES` the config can
resolve to.  Each captured call is then validated:

* **KC201** — every block shape divides its (padded) operand shape, and
  the output blocks visited by the grid cover the whole output;
* **KC202** — the index map stays in bounds over the full grid;
* **KC203** — ``ref`` and ``pallas`` entries agree on output pytree
  structure, shapes, and dtypes (``eval_shape`` both sides);
* **KC204** — the VMEM-resident bytes of one grid step (all blocks,
  x2 for the double-buffered pipeline) fit the ~16 MB/core budget.

Sample sizes are perturbed per invocation (a module-level counter) so
the pjit trace cache can never serve a cached jaxpr and starve the
capture; an entry that traces without reaching ``pallas_call`` — or
raises — is itself a finding (**KC200**).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
import math
from pathlib import Path

from repro.analysis.findings import Finding

VMEM_BYTES = 16 * 1024 * 1024     # per-core VMEM, TPU v5e
DOUBLE_BUFFER = 2                 # grid pipeline keeps two block sets live
MAX_GRID_ENUM = 4096              # full index-map sweep below this many steps

# perturb sample N per invocation: a fresh shape defeats the pjit trace
# cache, so pallas_call is really re-entered and captured every time
_INVOCATION = itertools.count()


@dataclasses.dataclass
class CapturedCall:
    """One ``pl.pallas_call`` site, as captured during abstract tracing."""
    kernel: object
    grid: tuple[int, ...]
    in_specs: list
    out_specs: list
    out_shape: list             # jax.ShapeDtypeStruct leaves
    arg_shapes: list            # [(shape, dtype)] of the runtime operands

    def location(self, repo_root: Path | None = None) -> tuple[str, int]:
        fn = self.kernel
        while isinstance(fn, functools.partial):
            fn = fn.func
        code = getattr(fn, "__code__", None)
        if code is None:
            return "<unknown>", 0
        path = Path(code.co_filename)
        if repo_root is not None:
            try:
                path = path.relative_to(repo_root)
            except ValueError:
                pass
        return path.as_posix(), code.co_firstlineno


@contextlib.contextmanager
def capture_pallas_calls(records: list[CapturedCall]):
    """Wrap ``pl.pallas_call`` so traced calls append to ``records``."""
    from jax.experimental import pallas as pl

    orig = pl.pallas_call

    def _norm_specs(specs):
        if specs is None:
            return []
        return list(specs) if isinstance(specs, (list, tuple)) else [specs]

    def wrapper(*args, **kwargs):
        kernel = args[0] if args else kwargs.get("kernel")
        inner = orig(*args, **kwargs)

        def recorded(*call_args):
            grid = kwargs.get("grid", ())
            if isinstance(grid, int):
                grid = (grid,)
            out_shape = kwargs.get("out_shape")
            out_leaves = list(out_shape) \
                if isinstance(out_shape, (list, tuple)) else [out_shape]
            records.append(CapturedCall(
                kernel=kernel,
                grid=tuple(grid) if grid else (),
                in_specs=_norm_specs(kwargs.get("in_specs")),
                out_specs=_norm_specs(kwargs.get("out_specs")),
                out_shape=out_leaves,
                arg_shapes=[(tuple(a.shape), a.dtype) for a in call_args],
            ))
            return inner(*call_args)

        return recorded

    pl.pallas_call = wrapper
    try:
        yield
    finally:
        pl.pallas_call = orig


# ------------------------------------------------------------ validation --

def _grid_points(grid: tuple[int, ...]):
    """All grid index tuples, or a corner/edge sample for huge grids."""
    total = math.prod(grid) if grid else 0
    if total <= MAX_GRID_ENUM:
        return list(itertools.product(*[range(g) for g in grid])), True
    corners = itertools.product(*[sorted({0, g // 2, g - 1}) for g in grid])
    return list(corners), False


def _block_dims(spec, shape):
    """Concrete per-axis block sizes (None -> whole axis)."""
    bs = getattr(spec, "block_shape", None)
    if bs is None:
        return tuple(shape)
    return tuple(shape[d] if b is None else int(b) for d, b in enumerate(bs))


def validate_call(cap: CapturedCall, name: str,
                  repo_root: Path | None = None) -> list[Finding]:
    """Check one captured pallas_call's geometry; returns findings."""
    path, line = cap.location(repo_root)
    findings: list[Finding] = []

    def emit(code, message):
        findings.append(Finding(code=code, path=path, line=line,
                                message=message, scope=name))

    # omitted specs mean "whole array as one block" — pad with None so the
    # operand still counts toward VMEM (a missing spec is how a whole-array
    # blowout hides)
    in_specs = list(cap.in_specs) \
        + [None] * (len(cap.arg_shapes) - len(cap.in_specs))
    out_specs = list(cap.out_specs) \
        + [None] * (len(cap.out_shape) - len(cap.out_specs))
    operands = [  # (role, index, shape, dtype, spec)
        ("in", i, shape, dtype, spec)
        for i, ((shape, dtype), spec)
        in enumerate(zip(cap.arg_shapes, in_specs))
    ] + [
        ("out", i, tuple(o.shape), o.dtype, spec)
        for i, (o, spec) in enumerate(zip(cap.out_shape, out_specs))
    ]

    pts, exhaustive = _grid_points(cap.grid)
    vmem = 0
    for role, i, shape, dtype, spec in operands:
        label = f"{role}_specs[{i}]"
        bs = getattr(spec, "block_shape", None)
        if bs is not None and len(bs) != len(shape):
            emit("KC201", f"{label}: block rank {len(bs)} != operand rank "
                          f"{len(shape)} (shape {shape})")
            continue
        block = _block_dims(spec, shape)
        vmem += math.prod(block) * dtype.itemsize
        bad_axes = [d for d in range(len(shape)) if shape[d] % block[d] != 0]
        if bad_axes:
            emit("KC201",
                 f"{label}: block {block} does not evenly tile operand "
                 f"{shape} on axes {bad_axes} — pad the operand to a tile "
                 "multiple (or the kernel must mask the ragged edge)")
        index_map = getattr(spec, "index_map", None)
        if index_map is None or not cap.grid:
            continue
        visited: set[tuple[int, ...]] = set()
        oob_reported = False
        for pt in pts:
            idx = index_map(*pt)
            if not isinstance(idx, tuple):
                idx = (idx,)
            idx = tuple(int(v) for v in idx)
            if len(idx) != len(shape):
                emit("KC202", f"{label}: index map returns rank {len(idx)} "
                              f"for rank-{len(shape)} operand")
                oob_reported = True
                break
            visited.add(idx)
            if not oob_reported and any(
                    v < 0 or (v + 1) * block[d] > shape[d]
                    for d, v in enumerate(idx)):
                emit("KC202",
                     f"{label}: index map sends grid point {pt} to block "
                     f"{idx} — element offset "
                     f"{tuple(v * b for v, b in zip(idx, block))} + block "
                     f"{block} escapes operand {shape}")
                oob_reported = True
        if role == "out" and exhaustive and not oob_reported and not bad_axes:
            required = set(itertools.product(
                *[range(shape[d] // block[d]) for d in range(len(shape))]))
            missing = required - visited
            if missing:
                emit("KC201",
                     f"{label}: grid {cap.grid} never writes output "
                     f"block(s) {sorted(missing)[:4]}"
                     f"{'...' if len(missing) > 4 else ''} of {shape} — "
                     "uncovered output is left uninitialized")

    resident = vmem * DOUBLE_BUFFER
    if resident > VMEM_BYTES:
        emit("KC204",
             f"one grid step keeps {vmem / 2**20:.1f} MB of blocks resident "
             f"(x{DOUBLE_BUFFER} double-buffered = {resident / 2**20:.1f} MB) "
             f"> {VMEM_BYTES / 2**20:.0f} MB VMEM budget")
    return findings


# ---------------------------------------------------------- sample shapes --

def _samples(n: int):
    """name -> (static kwargs, arg structs) at the config-permitted maxima.

    ``n`` (the point count) is perturbed per invocation; the widths are the
    envelope the checker certifies: ``MAX_N_NEIGHBORS`` for neighbor-major
    tiles, ``MAX_N_BOXES`` for the FFT node lattice, D=1024 for post-PCA
    inputs (see docs/KERNELS.md).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.fft_repulsion import MAX_N_BOXES, P_ORDER
    from repro.core.tsne import MAX_N_NEIGHBORS

    f32 = jnp.float32
    i32 = jnp.int32
    s = jax.ShapeDtypeStruct
    k = MAX_N_NEIGHBORS
    nodes = MAX_N_BOXES * (P_ORDER - 1) + 1
    return {
        "morton_encode": ({}, (s((n, 2), f32), s((2,), f32), s((), f32))),
        "pairwise_sq_dists": ({}, (s((n, 1024), f32), s((n + 115, 1024), f32))),
        "attractive_ell": ({}, (s((n, 2), f32), s((n, k), i32), s((n, k), f32))),
        "bsp_search": ({}, (s((n, k), f32), s((), f32))),
        "fft_spread": (dict(nodes=nodes),
                       (s((n, 2), i32), s((n, P_ORDER), f32),
                        s((n, P_ORDER), f32), s((n, 3), f32))),
        "fft_gather": ({}, (s((nodes, nodes, 4), f32), s((n, 2), i32),
                            s((n, P_ORDER), f32), s((n, P_ORDER), f32))),
    }


def check_kernel_callable(name: str, fn, args, kwargs: dict | None = None,
                          repo_root: Path | None = None) -> list[Finding]:
    """Trace ``fn(*args, **kwargs)`` abstractly and validate every
    ``pallas_call`` it reaches.  ``args`` are ``jax.ShapeDtypeStruct``
    leaves (the declared operand shapes); ``kwargs`` are static."""
    import jax

    records: list[CapturedCall] = []
    target = functools.partial(fn, **kwargs) if kwargs else fn
    try:
        with capture_pallas_calls(records):
            jax.eval_shape(target, *args)
    except Exception as exc:  # noqa: BLE001 — the failure IS the finding
        return [Finding(
            code="KC200", path=f"<{name}>", line=0, scope=name,
            message=f"tracing raised {type(exc).__name__}: {exc}")]
    if not records:
        return [Finding(
            code="KC200", path=f"<{name}>", line=0, scope=name,
            message="no pallas_call reached during trace — nothing to "
                    "validate (wrapper dispatched elsewhere?)")]
    findings: list[Finding] = []
    for cap in records:
        findings.extend(validate_call(cap, name, repo_root=repo_root))
    return findings


def check_registry(repo_root: Path | None = None,
                   registry: dict | None = None) -> list[Finding]:
    """Validate every ``kernel_registry()`` entry: BlockSpec geometry on
    the pallas path (KC201/202/204) + ref/pallas output parity (KC203)."""
    import jax

    from repro.kernels.ops import kernel_registry

    reg = registry if registry is not None else kernel_registry()
    n = 517 + 256 * next(_INVOCATION)
    samples = _samples(n)
    findings: list[Finding] = []
    for name in sorted(reg):
        entry = reg[name]
        if name not in samples:
            findings.append(Finding(
                code="KC200", path=f"<{name}>", line=0, scope=name,
                message="registry entry has no declared operand shapes — "
                        "add a sample to analysis/kernel_contracts._samples"))
            continue
        kwargs, args = samples[name]
        findings.extend(check_kernel_callable(
            name, entry["pallas"], args, kwargs, repo_root=repo_root))
        # ref/pallas parity on abstract outputs
        try:
            ref_fn = functools.partial(entry["ref"], **kwargs) \
                if kwargs else entry["ref"]
            pal_fn = functools.partial(entry["pallas"], **kwargs) \
                if kwargs else entry["pallas"]
            ref_out = jax.eval_shape(ref_fn, *args)
            pal_out = jax.eval_shape(pal_fn, *args)
        except Exception as exc:  # noqa: BLE001
            findings.append(Finding(
                code="KC200", path=f"<{name}>", line=0, scope=name,
                message=f"ref/pallas eval_shape raised "
                        f"{type(exc).__name__}: {exc}"))
            continue
        ref_leaves = jax.tree_util.tree_leaves(ref_out)
        pal_leaves = jax.tree_util.tree_leaves(pal_out)
        if len(ref_leaves) != len(pal_leaves) or any(
                r.shape != p.shape or r.dtype != p.dtype
                for r, p in zip(ref_leaves, pal_leaves)):
            findings.append(Finding(
                code="KC203", path=f"<{name}>", line=0, scope=name,
                message=f"ref outputs "
                        f"{[(l.shape, str(l.dtype)) for l in ref_leaves]} != "
                        f"pallas outputs "
                        f"{[(l.shape, str(l.dtype)) for l in pal_leaves]}"))
    return findings
