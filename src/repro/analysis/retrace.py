"""AST pass for jit/retrace hazards (codes RT101-RT105).

The bug classes this catches are the ones the repo has already paid for
once each (PR 6's momentum-as-operand fix, the ``TRACE_LOG`` replacement,
the ``neighbor_options`` hashability normalization):

* **RT101** — host syncs inside a jitted function: ``.item()``,
  ``float()``/``int()``/``bool()`` applied to a traced parameter,
  ``np.asarray``/``np.array`` of a traced parameter, and
  ``.block_until_ready()`` under ``jit``;
* **RT102** — ``jax.jit`` applied inside a function body (a fresh wrapper
  — and compile cache — per call), including jit-decorated defs nested in
  a function, with the closure-captured Python scalars named (each
  capture is baked at trace time: stale constants at best, a
  retrace-per-value pattern when the closure is rebuilt);
* **RT103** — ``static_argnames`` entries whose parameter is
  dict/list/set-valued (unhashable, or insertion-order-sensitive when
  wrapped) by default or annotation;
* **RT104** — ``time.*`` / ``random.*`` / ``np.random.*`` calls under
  ``jit`` (trace-time constants masquerading as runtime values);
* **RT105** — ``block_until_ready`` outside a Tracer span anywhere in a
  module (the sync happens, but the profile misattributes it; use
  ``sp.sync``).

Static findings are confirmable at runtime: every jitted hot path carries
a :class:`repro.obs.RecompileProbe`, so a flagged retrace hazard shows up
as a growing ``recompiles.*`` counter in service ``stats()`` snapshots.

The pass is source -> findings (:func:`scan_source`); file iteration,
pragma application, and baselines live in :mod:`repro.analysis.cli`.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding

# module paths exempt from RT105 (they implement the tracer machinery)
EXEMPT_PATH_PARTS = ("obs",)

_HOST_CASTS = {"float", "int", "bool"}
_NP_SYNC_FUNCS = {"asarray", "array"}
_TIME_FUNCS = {"time", "perf_counter", "monotonic", "process_time",
               "thread_time", "clock_gettime"}
_MUTABLE_LITERALS = (ast.Dict, ast.List, ast.Set, ast.DictComp,
                     ast.ListComp, ast.SetComp)
_UNHASHABLE_ANNOT = ("dict", "Dict", "list", "List", "set", "Set",
                     "Mapping", "MutableMapping")


class _Aliases:
    """Import names seen at module level, resolved to what we care about."""

    def __init__(self, tree: ast.Module):
        self.jax: set[str] = set()          # `import jax [as j]`
        self.jit: set[str] = set()          # `from jax import jit [as j]`
        self.np: set[str] = set()           # `import numpy [as np]`
        self.partial: set[str] = set()      # `from functools import partial`
        self.functools: set[str] = set()
        self.time_mod: set[str] = set()
        self.time_fn: set[str] = set()      # `from time import perf_counter`
        self.random_mod: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    tgt = a.asname or a.name.split(".")[0]
                    if a.name == "jax" or a.name.startswith("jax."):
                        self.jax.add(tgt if a.asname or a.name == "jax"
                                     else "jax")
                    elif a.name == "numpy" or a.name.startswith("numpy."):
                        self.np.add(tgt if a.asname or a.name == "numpy"
                                    else "numpy")
                    elif a.name == "functools":
                        self.functools.add(tgt)
                    elif a.name == "time":
                        self.time_mod.add(tgt)
                    elif a.name == "random":
                        self.random_mod.add(tgt)
            elif isinstance(node, ast.ImportFrom):
                for a in node.names:
                    tgt = a.asname or a.name
                    if node.module == "jax" and a.name == "jit":
                        self.jit.add(tgt)
                    elif node.module == "functools" and a.name == "partial":
                        self.partial.add(tgt)
                    elif node.module == "time" and a.name in _TIME_FUNCS:
                        self.time_fn.add(tgt)

    # -- expression classifiers ------------------------------------------
    def is_jit_expr(self, node: ast.expr) -> bool:
        """``jax.jit`` / ``jit`` (by any imported alias)."""
        if isinstance(node, ast.Name):
            return node.id in self.jit
        return (isinstance(node, ast.Attribute) and node.attr == "jit"
                and isinstance(node.value, ast.Name)
                and node.value.id in self.jax)

    def is_partial_expr(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.partial
        return (isinstance(node, ast.Attribute) and node.attr == "partial"
                and isinstance(node.value, ast.Name)
                and node.value.id in self.functools)

    def is_np_attr(self, node: ast.expr, names: set[str]) -> bool:
        return (isinstance(node, ast.Attribute) and node.attr in names
                and isinstance(node.value, ast.Name)
                and node.value.id in self.np)


def _static_argnames(call: ast.Call) -> set[str]:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names: set[str] = set()
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                        names.add(elt.value)
            return names
    return set()


def _jit_decoration(fn: ast.FunctionDef, al: _Aliases):
    """(is_jitted, static_argnames) from the decorator list."""
    for dec in fn.decorator_list:
        if al.is_jit_expr(dec):
            return True, set()
        if isinstance(dec, ast.Call):
            if al.is_jit_expr(dec.func):                   # @jax.jit(...)
                return True, _static_argnames(dec)
            if al.is_partial_expr(dec.func) and dec.args \
                    and al.is_jit_expr(dec.args[0]):       # @partial(jax.jit)
                return True, _static_argnames(dec)
    return False, set()


def _annotation_src(node: ast.expr | None) -> str:
    return ast.unparse(node) if node is not None else ""


def _all_params(fn: ast.FunctionDef) -> list[ast.arg]:
    a = fn.args
    return [*a.posonlyargs, *a.args, *a.kwonlyargs]


def _param_defaults(fn: ast.FunctionDef) -> dict[str, ast.expr]:
    """param name -> default expression (positional and keyword-only)."""
    a = fn.args
    out: dict[str, ast.expr] = {}
    pos = [*a.posonlyargs, *a.args]
    for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        out[arg.arg] = default
    for arg, default in zip(a.kwonlyargs, a.kw_defaults):
        if default is not None:
            out[arg.arg] = default
    return out


def _assigned_names(fn: ast.FunctionDef) -> set[str]:
    """Names bound anywhere in ``fn``'s own body (locals for captures)."""
    names = {a.arg for a in _all_params(fn)}
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
    return names


class _Scanner(ast.NodeVisitor):
    def __init__(self, relpath: str, al: _Aliases):
        self.relpath = relpath
        self.al = al
        self.findings: list[Finding] = []
        self.scope: list[str] = []           # qualname parts
        self.fn_stack: list[ast.FunctionDef] = []
        # per-jitted-function context while inside one
        self.jit_depth = 0
        self.traced_params: set[str] = set()
        self.span_depth = 0
        self.exempt_sync = any(p in relpath.split("/")
                               for p in EXEMPT_PATH_PARTS)
        self._decorator_calls: set[int] = set()   # id() of decorator exprs

    # ------------------------------------------------------------ helpers
    def _emit(self, code: str, node: ast.AST, message: str):
        self.findings.append(Finding(
            code=code, path=self.relpath, line=node.lineno, message=message,
            scope=".".join(self.scope)))

    def _check_static_hashability(self, fn: ast.FunctionDef,
                                  statics: set[str]):
        defaults = _param_defaults(fn)
        annots = {a.arg: _annotation_src(a.annotation)
                  for a in _all_params(fn)}
        for name in sorted(statics):
            d = defaults.get(name)
            if d is not None and (
                    isinstance(d, _MUTABLE_LITERALS)
                    or (isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                        and d.func.id in ("dict", "list", "set"))):
                self._emit("RT103", fn,
                           f"static arg {name!r} of {fn.name!r} defaults to a "
                           f"{type(d).__name__.lower()} — unhashable/"
                           "insertion-ordered under jit")
                continue
            ann = annots.get(name, "")
            if any(tok in ann.replace(" ", "").replace("|", ",").split(",")
                   or ann.startswith(f"{tok}[") for tok in _UNHASHABLE_ANNOT):
                self._emit("RT103", fn,
                           f"static arg {name!r} of {fn.name!r} is annotated "
                           f"{ann!r} — unhashable/insertion-ordered under jit")

    def _captured_names(self, fn: ast.FunctionDef) -> list[str]:
        """Loads in ``fn`` bound as locals of an enclosing function."""
        if not self.fn_stack:
            return []
        enclosing: set[str] = set()
        for outer in self.fn_stack:
            enclosing |= _assigned_names(outer)
        own = _assigned_names(fn)
        loads = {n.id for n in ast.walk(fn)
                 if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
        return sorted((loads & enclosing) - own)

    # ------------------------------------------------------------- visits
    def visit_ClassDef(self, node: ast.ClassDef):
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    def _visit_function(self, node: ast.FunctionDef):
        jitted, statics = _jit_decoration(node, self.al)
        # decorators execute in the *enclosing* scope — visit them before
        # entering the function so @partial(jax.jit, ...) on a module-level
        # def is not mistaken for a jit call inside the function body
        for dec in node.decorator_list:
            self._decorator_calls.add(id(dec))
            self.visit(dec)
        self.scope.append(node.name)
        if jitted:
            self._check_static_hashability(node, statics)
            if self.fn_stack and self.fn_stack[-1].name != "__init__":
                caps = self._captured_names(node)
                cap = (" (captures " + ", ".join(repr(c) for c in caps) + ")"
                       if caps else "")
                self._emit("RT102", node,
                           f"jit-decorated {node.name!r} defined inside "
                           f"{self.fn_stack[-1].name!r} — fresh compile "
                           f"cache per call{cap}")
        self.fn_stack.append(node)
        if jitted:
            self.jit_depth += 1
            prev = self.traced_params
            self.traced_params = {a.arg for a in _all_params(node)} \
                - statics - {"self", "cls"}
        for field, value in ast.iter_fields(node):
            if field == "decorator_list":
                continue
            for child in (value if isinstance(value, list) else [value]):
                if isinstance(child, ast.AST):
                    self.visit(child)
        if jitted:
            self.jit_depth -= 1
            self.traced_params = prev
        self.fn_stack.pop()
        self.scope.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_With(self, node: ast.With):
        is_span = any(
            isinstance(item.context_expr, ast.Call)
            and ((isinstance(item.context_expr.func, ast.Attribute)
                  and item.context_expr.func.attr in ("span", "trace"))
                 or (isinstance(item.context_expr.func, ast.Name)
                     and item.context_expr.func.id == "trace"))
            for item in node.items)
        if is_span:
            self.span_depth += 1
        self.generic_visit(node)
        if is_span:
            self.span_depth -= 1

    def visit_Call(self, node: ast.Call):
        al = self.al
        func = node.func
        in_jit = self.jit_depth > 0

        # jit applied as an expression inside a function body (RT102);
        # __init__ is the sanctioned place to build per-instance wrappers
        if (al.is_jit_expr(func) or
                (al.is_partial_expr(func) and node.args
                 and al.is_jit_expr(node.args[0]))):
            if self.fn_stack and self.fn_stack[-1].name != "__init__" \
                    and id(node) not in self._decorator_calls:
                self._emit("RT102", node,
                           f"jax.jit(...) called inside "
                           f"{self.fn_stack[-1].name!r} — fresh compile "
                           "cache per call")

        if isinstance(func, ast.Attribute):
            if func.attr == "item" and not node.args and in_jit:
                self._emit("RT101", node,
                           ".item() inside a jitted function forces a "
                           "host sync")
            elif func.attr == "block_until_ready":
                if in_jit:
                    self._emit("RT101", node,
                               "block_until_ready inside a jitted function")
                elif self.span_depth == 0 and not self.exempt_sync:
                    self._emit("RT105", node,
                               "block_until_ready outside a Tracer span — "
                               "sync is invisible to the profile")
            elif in_jit and al.is_np_attr(func, _NP_SYNC_FUNCS) and node.args \
                    and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id in self.traced_params:
                self._emit("RT101", node,
                           f"np.{func.attr}({node.args[0].id}) materializes a "
                           "traced value on host inside a jitted function")
            elif in_jit and isinstance(func.value, ast.Name):
                base = func.value.id
                if base in al.time_mod and func.attr in _TIME_FUNCS:
                    self._emit("RT104", node,
                               f"time.{func.attr}() under jit is a "
                               "trace-time constant")
                elif base in al.random_mod:
                    self._emit("RT104", node,
                               f"random.{func.attr}() under jit is a "
                               "trace-time constant; use jax.random")
            if (in_jit and isinstance(func.value, ast.Attribute)
                    and func.value.attr == "random"
                    and isinstance(func.value.value, ast.Name)
                    and func.value.value.id in al.np):
                self._emit("RT104", node,
                           f"np.random.{func.attr}() under jit is a "
                           "trace-time constant; use jax.random")
        elif isinstance(func, ast.Name):
            if in_jit and func.id in _HOST_CASTS and len(node.args) == 1 \
                    and isinstance(node.args[0], ast.Name) \
                    and node.args[0].id in self.traced_params:
                self._emit("RT101", node,
                           f"{func.id}({node.args[0].id}) on a traced "
                           "parameter forces a host sync inside a jitted "
                           "function")
            elif in_jit and func.id in al.time_fn:
                self._emit("RT104", node,
                           f"{func.id}() under jit is a trace-time constant")
        self.generic_visit(node)


def scan_source(source: str, relpath: str) -> list[Finding]:
    """Run the retrace pass over one module's source."""
    tree = ast.parse(source, filename=relpath)
    al = _Aliases(tree)
    sc = _Scanner(relpath, al)
    sc.visit(tree)
    return sc.findings
