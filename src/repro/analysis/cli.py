"""``python -m repro.analysis`` — run the static-analysis suite.

Three passes over the tree (selectable with ``--passes``):

* ``retrace``     — jit/retrace hazards (RT1xx), AST over ``.py`` files;
* ``concurrency`` — lock discipline in threaded classes (CC3xx);
* ``kernels``     — Pallas BlockSpec/VMEM contracts of every
  ``kernel_registry()`` entry (KC2xx; needs jax importable, ignores the
  path arguments).

Typical invocations::

    python -m repro.analysis                     # report, exit 0
    python -m repro.analysis --gate              # CI: fail on new findings
    python -m repro.analysis --gate --fix-hints  # ...with per-code hints
    python -m repro.analysis src/repro/embed     # scope to a subtree
    python -m repro.analysis --write-baseline    # prune fixed entries

The gate compares against the checked-in ``ANALYSIS_BASELINE.json``:
*new* findings (not in the baseline, severity >= warning, not pragma-
suppressed) fail the build; *stale* entries (fixed findings still in the
baseline) are reported so ``--write-baseline`` can prune them.
``--write-baseline`` refuses to *add* entries unless ``--allow-grow`` is
given — the baseline only shrinks.  See docs/ANALYSIS.md.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
from pathlib import Path

from repro.analysis import concurrency, findings as fmod, retrace
from repro.analysis.findings import Finding, Severity

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_SCAN = REPO_ROOT / "src" / "repro"
DEFAULT_BASELINE = REPO_ROOT / "ANALYSIS_BASELINE.json"
PASSES = ("retrace", "concurrency", "kernels")


def iter_py_files(paths: list[Path]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def _relpath(path: Path) -> str:
    try:
        return path.resolve().relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return path.as_posix()


def scan_files(paths: list[Path], passes: tuple[str, ...]) -> list[Finding]:
    """AST passes + pragma application over every file under ``paths``."""
    out: list[Finding] = []
    for path in iter_py_files(paths):
        source = path.read_text()
        rel = _relpath(path)
        file_findings: list[Finding] = []
        if "retrace" in passes:
            file_findings.extend(retrace.scan_source(source, rel))
        if "concurrency" in passes:
            file_findings.extend(concurrency.scan_source(source, rel))
        out.extend(fmod.apply_pragmas(file_findings,
                                      fmod.scan_pragmas(source)))
    return out


def run_kernel_pass(kernels_from: str | None = None) -> list[Finding]:
    from repro.analysis import kernel_contracts

    if kernels_from:
        mod = importlib.import_module(kernels_from)
        out: list[Finding] = []
        for name, fn, args, kwargs in mod.kernel_cases():
            out.extend(kernel_contracts.check_kernel_callable(
                name, fn, args, kwargs, repo_root=REPO_ROOT))
        return out
    return kernel_contracts.check_registry(repo_root=REPO_ROOT)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: retrace hazards, Pallas kernel "
                    "contracts, lock discipline")
    ap.add_argument("paths", nargs="*", type=Path,
                    help=f"files/dirs to scan (default: {DEFAULT_SCAN})")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {','.join(PASSES)}")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline JSON (missing file = empty baseline)")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 on findings not covered by the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline: prune fixed entries "
                         "(never adds unless --allow-grow)")
    ap.add_argument("--allow-grow", action="store_true",
                    help="let --write-baseline record NEW findings too")
    ap.add_argument("--fix-hints", action="store_true",
                    help="print a fix hint under each finding")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print pragma-suppressed findings")
    ap.add_argument("--min-severity", default="warning",
                    choices=[s.name.lower() for s in Severity],
                    help="severity floor for gating/baseline (default: "
                         "warning; the report always shows everything)")
    ap.add_argument("--kernels-from", default=None, metavar="MODULE",
                    help="validate MODULE.kernel_cases() instead of the "
                         "repo kernel registry (fixture/testing hook)")
    args = ap.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = set(passes) - set(PASSES)
    if unknown:
        ap.error(f"unknown pass(es): {', '.join(sorted(unknown))} "
                 f"(known: {', '.join(PASSES)})")

    scan_paths = args.paths or [DEFAULT_SCAN]
    all_findings = scan_files(scan_paths, passes)
    if "kernels" in passes:
        all_findings.extend(run_kernel_pass(args.kernels_from))

    min_sev = Severity[args.min_severity.upper()]
    baseline = fmod.load_baseline(args.baseline)
    result = fmod.gate(all_findings, baseline, min_severity=min_sev)

    shown = [f for f in sorted(all_findings, key=lambda f: (f.path, f.line))
             if args.show_suppressed or not f.suppressed]
    if args.as_json:
        print(json.dumps({
            "findings": [dataclass_dict(f) for f in shown],
            "new": sorted(result.new),
            "known": sorted(result.known),
            "stale": sorted(result.stale),
        }, indent=2))
    else:
        for f in shown:
            print(f.format(fix_hints=args.fix_hints))
        n_sup = sum(f.suppressed for f in all_findings)
        print(f"\n{len(shown)} finding(s) "
              f"({len(result.new)} new, {len(result.known)} baselined, "
              f"{n_sup} suppressed by pragma, "
              f"{len(result.stale)} stale baseline entr{'y' if len(result.stale) == 1 else 'ies'})")
        if result.stale and not args.write_baseline:
            print("stale baseline entries — findings fixed since the "
                  "baseline was written; prune with --write-baseline:")
            for fp in sorted(result.stale):
                print(f"  - {fp}")

    if args.write_baseline:
        keep = dict(result.known)
        if args.allow_grow:
            keep.update(result.new)
        elif result.new:
            print(f"refusing to add {len(result.new)} new finding(s) to the "
                  "baseline (it only shrinks); fix them, pragma them, or "
                  "pass --allow-grow", file=sys.stderr)
            fmod.save_baseline(args.baseline, keep)
            return 1
        fmod.save_baseline(args.baseline, keep)
        print(f"baseline written: {args.baseline} ({len(keep)} entr"
              f"{'y' if len(keep) == 1 else 'ies'})")
        return 0

    if args.gate and not result.ok:
        print(f"\nGATE FAILED: {len(result.new)} finding(s) not in the "
              f"baseline ({args.baseline}):", file=sys.stderr)
        for fp, f in sorted(result.new.items()):
            print(f"  {f.format(fix_hints=args.fix_hints)}", file=sys.stderr)
        return 1
    return 0


def dataclass_dict(f: Finding) -> dict:
    return dict(code=f.code, severity=str(f.severity), path=f.path,
                line=f.line, scope=f.scope, message=f.message,
                suppressed=f.suppressed)


if __name__ == "__main__":
    sys.exit(main())
