"""Static-analysis suite: the compile-time half of the paper's methodology.

PR 7/8 added the *runtime* instruments (phase spans, ``recompiles.*``
probes, the roofline ranking); this package adds the *static* half — the
bug classes the repo keeps paying for are checked at commit time:

* :mod:`repro.analysis.retrace` — jit/retrace hazards (RT1xx);
* :mod:`repro.analysis.kernel_contracts` — Pallas BlockSpec / grid /
  VMEM contracts over ``kernels.ops.kernel_registry()`` (KC2xx);
* :mod:`repro.analysis.concurrency` — lock discipline in the threaded
  services (CC3xx);
* :mod:`repro.analysis.findings` — codes, severities,
  ``# repro-lint: disable=<code>`` pragmas, and the monotone baseline;
* :mod:`repro.analysis.cli` — ``python -m repro.analysis [--gate]``.

Finding codes and workflow are documented in docs/ANALYSIS.md.
"""
from repro.analysis.findings import (
    CODES, Finding, Severity, apply_pragmas, fingerprints, gate,
    load_baseline, save_baseline, scan_pragmas,
)

__all__ = [
    "CODES", "Finding", "Severity", "apply_pragmas", "fingerprints",
    "gate", "load_baseline", "save_baseline", "scan_pragmas",
]
