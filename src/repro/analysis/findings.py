"""Finding model shared by every analysis pass: codes, severities, pragmas,
and the checked-in baseline.

A :class:`Finding` is one diagnosed violation — a stable ``code`` (RT1xx
retrace hazards, KC2xx kernel contract breaches, CC3xx concurrency lint),
a severity from :data:`CODES`, a location, and a message/hint pair.  The
pieces that make findings *actionable over time* also live here:

* **pragmas** — ``# repro-lint: disable=RT101[,CC301|all]`` on the flagged
  line (or the line directly above it) suppresses matching findings; the
  scanner keeps them visible under ``--show-suppressed`` so waivers stay
  auditable;
* **baseline** — a JSON file of known-finding fingerprints.  The CI gate
  fails on any finding *not* in the baseline, and ``--write-baseline``
  only ever removes entries (``--allow-grow`` is the explicit override),
  so the baseline shrinks monotonically toward zero.

Fingerprints deliberately exclude line numbers — ``code:path:scope`` plus
a per-scope occurrence index — so unrelated edits to a file don't churn
the baseline.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import re
from collections import Counter
from pathlib import Path


class Severity(enum.IntEnum):
    INFO = 0
    WARNING = 1
    ERROR = 2

    def __str__(self) -> str:  # "error" in reports, not "Severity.ERROR"
        return self.name.lower()


# code -> (severity, one-line title, generic fix hint)
CODES: dict[str, tuple[Severity, str, str]] = {
    "RT101": (
        Severity.ERROR,
        "host sync inside a jitted function",
        "`.item()`, `float()`/`int()`/`bool()` on a traced value, "
        "`np.asarray`/`np.array` of a traced value, and "
        "`.block_until_ready()` force a device sync (or fail) at trace "
        "time; keep the value on-device (jnp ops) or hoist the sync out "
        "of the jitted function.",
    ),
    "RT102": (
        Severity.ERROR,
        "jax.jit created inside a function body",
        "a jit wrapper built per call starts a fresh compile cache every "
        "time — silent recompiles. Hoist the jitted function to module "
        "scope, or store the wrapper once (e.g. on `self` in `__init__`).",
    ),
    "RT103": (
        Severity.ERROR,
        "non-hashable static argument",
        "static_argnames entries must be hashable and order-stable; a "
        "dict/list/set-valued static arg either raises or (if wrapped) "
        "retraces per insertion order. Normalize to `tuple(sorted(...))` "
        "the way `TsneConfig.neighbor_options` does.",
    ),
    "RT104": (
        Severity.WARNING,
        "time/random call inside a jitted function",
        "`time.*` / `random.*` / `np.random.*` run once at trace time and "
        "bake a constant into the compiled program. Use `jax.random` with "
        "an explicit key, or pass the value in as an operand.",
    ),
    "RT105": (
        Severity.WARNING,
        "block_until_ready outside a Tracer span",
        "raw `block_until_ready` syncs are invisible to the profile and "
        "get misattributed; use `with tracer.span(...) as sp: sp.sync(x)` "
        "so the wait is charged to the phase that launched the work.",
    ),
    "KC200": (
        Severity.ERROR,
        "kernel contract could not be captured",
        "tracing the kernel entry point raised, or no pallas_call was "
        "reached — the checker cannot vouch for this kernel's BlockSpecs.",
    ),
    "KC201": (
        Severity.ERROR,
        "grid/block does not tile the operand",
        "block_shape must divide the (padded) operand shape on every axis "
        "and the grid must cover every output block; pad the operand to a "
        "tile multiple in the wrapper and slice the result (the "
        "pad-then-slice idiom in docs/KERNELS.md).",
    ),
    "KC202": (
        Severity.ERROR,
        "index map escapes the operand bounds",
        "an index_map result addresses a block beyond the operand extent "
        "for some grid point; check the map against grid=(...) and the "
        "padded shape.",
    ),
    "KC203": (
        Severity.ERROR,
        "ref/pallas output disagreement",
        "the pure-jnp oracle and the Pallas path return different "
        "shapes/dtypes for the same inputs; the wrapper must slice "
        "padding off and preserve the oracle's dtype.",
    ),
    "KC204": (
        Severity.ERROR,
        "per-tile VMEM budget exceeded",
        "the resident blocks of one grid step (x2 for double buffering) "
        "overflow the ~16 MB/core VMEM budget at a shape the config "
        "permits; shrink the tile or cap the offending config axis.",
    ),
    "CC301": (
        Severity.ERROR,
        "lock-inconsistent attribute access",
        "an attribute mutated under a lock is also touched without it (or "
        "vice versa) — either every cross-thread access takes the lock, "
        "or the attribute is single-thread-owned and should never be "
        "touched under the lock.",
    ),
    "CC302": (
        Severity.ERROR,
        "condition wait without a predicate loop",
        "`Condition.wait()` must sit in a `while <predicate>:` loop — "
        "wakeups are spurious and a bare or if-guarded wait() misses "
        "them.",
    ),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    path: str               # repo-relative POSIX path
    line: int               # 1-based
    message: str
    scope: str = ""         # dotted qualname of the enclosing def/class
    hint: str = ""          # finding-specific hint (falls back to CODES)
    suppressed: bool = False

    @property
    def severity(self) -> Severity:
        return CODES[self.code][0]

    @property
    def fix_hint(self) -> str:
        return self.hint or CODES[self.code][2]

    def format(self, fix_hints: bool = False) -> str:
        where = f"{self.path}:{self.line}"
        scope = f" [{self.scope}]" if self.scope else ""
        sup = " (suppressed)" if self.suppressed else ""
        out = f"{where}: {self.code} {self.severity}{sup}: {self.message}{scope}"
        if fix_hints:
            out += f"\n    hint: {self.fix_hint}"
        return out


def fingerprints(findings: list[Finding]) -> dict[str, Finding]:
    """Stable, line-number-free identity per finding.

    ``code:path:scope`` plus an occurrence index for repeats in the same
    scope, so editing unrelated lines never churns the baseline.
    """
    seen: Counter = Counter()
    out: dict[str, Finding] = {}
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        base = f"{f.code}:{f.path}:{f.scope}"
        idx = seen[base]
        seen[base] += 1
        out[f"{base}#{idx}"] = f
    return out


# ---------------------------------------------------------------- pragmas --

_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")


def scan_pragmas(source: str) -> dict[int, set[str]]:
    """line (1-based) -> set of codes disabled on that line (or ``{"all"}``)."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def apply_pragmas(findings: list[Finding],
                  pragmas: dict[int, set[str]]) -> list[Finding]:
    """Mark findings suppressed by a pragma on their line or the line above."""
    out = []
    for f in findings:
        codes = pragmas.get(f.line, set()) | pragmas.get(f.line - 1, set())
        if f.code in codes or "all" in codes:
            f = dataclasses.replace(f, suppressed=True)
        out.append(f)
    return out


# --------------------------------------------------------------- baseline --

BASELINE_VERSION = 1


def load_baseline(path: Path) -> dict[str, dict]:
    """fingerprint -> recorded metadata; a missing file is an empty baseline."""
    if not path.exists():
        return {}
    doc = json.loads(path.read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{doc.get('version')!r}")
    return doc["findings"]


def save_baseline(path: Path, findings: dict[str, Finding]) -> None:
    doc = {
        "version": BASELINE_VERSION,
        "findings": {
            fp: dict(code=f.code, path=f.path, scope=f.scope,
                     message=f.message)
            for fp, f in sorted(findings.items())
        },
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")


@dataclasses.dataclass
class GateResult:
    """Outcome of comparing a scan against the baseline."""
    new: dict[str, Finding]          # active findings not in the baseline
    known: dict[str, Finding]        # active findings covered by it
    stale: dict[str, dict]           # baseline entries that no longer fire

    @property
    def ok(self) -> bool:
        return not self.new


def gate(findings: list[Finding], baseline: dict[str, dict],
         min_severity: Severity = Severity.WARNING) -> GateResult:
    """Split active (unsuppressed, >= min_severity) findings by baseline."""
    active = [f for f in findings
              if not f.suppressed and f.severity >= min_severity]
    fps = fingerprints(active)
    new = {fp: f for fp, f in fps.items() if fp not in baseline}
    known = {fp: f for fp, f in fps.items() if fp in baseline}
    stale = {fp: meta for fp, meta in baseline.items() if fp not in fps}
    return GateResult(new=new, known=known, stale=stale)
