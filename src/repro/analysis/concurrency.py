"""AST pass for lock discipline in threaded classes (codes CC301-CC302).

Per class, the pass finds lock attributes (``self.x = threading.Lock() /
RLock() / Condition()``), then builds an attribute access map: every
``self.<attr>`` read, write (assignment, augmented/subscript assignment,
or a mutating method call like ``.append()`` / ``.popleft()``), the
method and line it happens in, and whether a ``with self.<lock>:`` block
is lexically held there.  ``__init__`` is construction — the instance
isn't shared yet — so it's excluded from the map.

Flagged:

* **CC301** — an attribute with at least one *locked write* that is also
  accessed without the lock, or (the inverse hazard) unlocked *writes*
  to an attribute other methods access under the lock.  Either every
  cross-thread access takes the lock or none should.
* **CC302** — ``Condition.wait()`` with no enclosing ``while`` loop:
  wakeups are spurious, the predicate must be re-checked in a loop
  (``wait_for`` embeds the loop and is not flagged).

Single-thread-owned attributes (never touched under any lock) produce no
findings — the lint enforces *consistency* of an adopted lock protocol,
not lock-everything.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.findings import Finding

_LOCK_TYPES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
_MUTATORS = {
    "append", "appendleft", "extend", "extendleft", "insert", "pop",
    "popleft", "remove", "clear", "add", "discard", "update", "setdefault",
    "sort", "reverse", "popitem",
}


@dataclasses.dataclass(frozen=True)
class Access:
    attr: str
    kind: str           # "read" | "write"
    method: str
    line: int
    locked: bool


def _lock_attrs(cls: ast.ClassDef) -> dict[str, str]:
    """self-attr name -> lock type, from assignments anywhere in the class."""
    out: dict[str, str] = {}
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fn = node.value.func
            type_name = None
            if isinstance(fn, ast.Attribute) and fn.attr in _LOCK_TYPES \
                    and isinstance(fn.value, ast.Name) \
                    and fn.value.id == "threading":
                type_name = fn.attr
            elif isinstance(fn, ast.Name) and fn.id in _LOCK_TYPES:
                type_name = fn.id
            if type_name:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == "self":
                        out[tgt.attr] = type_name
    return out


def _self_attr(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


class _MethodWalker:
    """Collect accesses + wait() calls in one method, tracking held locks."""

    def __init__(self, method: str, locks: dict[str, str]):
        self.method = method
        self.locks = locks
        self.accesses: list[Access] = []
        self.waits: list[tuple[str, int, bool]] = []  # (attr, line, in_while)
        self._held = 0
        self._while_depth = 0
        self._write_nodes: set[int] = set()   # id() of Attribute nodes that
        # are the *target* of a write (so the generic read walk skips them)

    def _record(self, attr: str, kind: str, line: int):
        if attr in self.locks:
            return
        self.accesses.append(Access(attr=attr, kind=kind, method=self.method,
                                    line=line, locked=self._held > 0))

    def walk(self, node: ast.AST):
        if isinstance(node, ast.With):
            held_here = 0
            for item in node.items:
                ctx = item.context_expr
                attr = _self_attr(ctx)
                if attr is None and isinstance(ctx, ast.Call):
                    attr = _self_attr(ctx.func)   # e.g. self._cv.acquire()
                if attr in self.locks:
                    held_here += 1
            self._held += held_here
            for item in node.items:
                self.walk(item.context_expr)
            for child in node.body:
                self.walk(child)
            self._held -= held_here
            return
        if isinstance(node, ast.While):
            self._while_depth += 1
            for child in ast.iter_child_nodes(node):
                self.walk(child)
            self._while_depth -= 1
            return
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                base = tgt
                if isinstance(base, (ast.Subscript,)):
                    base = base.value
                attr = _self_attr(base)
                if attr is not None:
                    self._record(attr, "write", node.lineno)
                    self._write_nodes.add(id(base))
                    if isinstance(node, ast.AugAssign):
                        # += reads, then writes
                        self._record(attr, "read", node.lineno)
                else:
                    self.walk(tgt)
            if isinstance(node, ast.AnnAssign):
                if node.value is not None:
                    self.walk(node.value)
            else:
                self.walk(node.value)
            return
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute):
                recv_attr = _self_attr(fn.value)
                if recv_attr is not None:
                    if recv_attr in self.locks and fn.attr == "wait":
                        self.waits.append((recv_attr, node.lineno,
                                           self._while_depth > 0))
                    elif fn.attr in _MUTATORS:
                        self._record(recv_attr, "write", node.lineno)
                        self._write_nodes.add(id(fn.value))
            for child in ast.iter_child_nodes(node):
                self.walk(child)
            return
        attr = _self_attr(node)
        if attr is not None and id(node) not in self._write_nodes \
                and isinstance(node.ctx, ast.Load):
            self._record(attr, "read", node.lineno)
        for child in ast.iter_child_nodes(node):
            self.walk(child)


def _scan_class(cls: ast.ClassDef, relpath: str) -> list[Finding]:
    locks = _lock_attrs(cls)
    if not locks:
        return []
    findings: list[Finding] = []
    accesses: list[Access] = []
    for node in cls.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        w = _MethodWalker(node.name, locks)
        for child in node.body:
            w.walk(child)
        for attr, line, in_while in w.waits:
            if not in_while:
                findings.append(Finding(
                    code="CC302", path=relpath, line=line,
                    scope=f"{cls.name}.{node.name}",
                    message=f"self.{attr}.wait() without an enclosing "
                            "while-predicate loop (spurious wakeups)"))
        if node.name != "__init__":
            accesses.extend(w.accesses)

    by_attr: dict[str, list[Access]] = {}
    for a in accesses:
        by_attr.setdefault(a.attr, []).append(a)
    for attr, accs in sorted(by_attr.items()):
        locked = [a for a in accs if a.locked]
        unlocked = [a for a in accs if not a.locked]
        if not locked or not unlocked:
            continue
        locked_writes = [a for a in locked if a.kind == "write"]
        flagged: list[Access] = []
        if locked_writes:
            flagged = unlocked                  # protocol: attr is lock-guarded
        elif any(a.kind == "write" for a in unlocked):
            flagged = [a for a in unlocked if a.kind == "write"]
        for a in flagged:
            other = locked_writes[0] if locked_writes else locked[0]
            findings.append(Finding(
                code="CC301", path=relpath, line=a.line,
                scope=f"{cls.name}.{a.method}",
                message=f"self.{attr} {a.kind} without the lock, but "
                        f"{other.method}:{other.line} accesses it under "
                        "one"))
    return findings


def scan_source(source: str, relpath: str) -> list[Finding]:
    """Run the concurrency pass over one module's source."""
    tree = ast.parse(source, filename=relpath)
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_scan_class(node, relpath))
    return findings
