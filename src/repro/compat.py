"""Cross-version JAX shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
namespace, and its replication-check kwarg was renamed ``check_rep`` ->
``check_vma`` along the way.  Every shard_map call site in this repo goes
through this wrapper so the rest of the code can use the modern spelling
regardless of the pinned jax version.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6: public API
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = (
    "check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    kw = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
