"""Architecture + parallelism configuration schema.

One ``ArchConfig`` instance per assigned architecture (see the sibling
modules); ``reduced()`` derives the CPU smoke-test configuration of the same
family.  Shapes are the assigned (seq_len, global_batch) cells.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    n_shared: int = 2
    expert_d_ff: int = 1408
    shared_d_ff: int | None = None          # default: n_shared * expert_d_ff
    first_dense_layers: int = 1
    dense_d_ff: int = 10944                 # d_ff of the leading dense layers
    router: Literal["softmax", "sigmoid_bias"] = "softmax"
    norm_topk_prob: bool = False
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int | None = None          # None = full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64                     # N
    head_dim: int = 64                      # P
    expand: int = 2                         # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128                        # SSD chunk length
    # hybrid (zamba2-style): one *shared* attention block applied every
    # `shared_stride` SSM layers (0 = pure SSM)
    shared_stride: int = 0
    shared_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    # "scan" = faithful per-step recurrence; "chunked" = parallel chunked
    # WKV (one state touch per chunk — §Perf hillclimb, default for train)
    wkv_mode: str = "chunked"


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 4
    n_frames: int = 1500                    # stubbed audio frontend length


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 2880                   # anyres tiling stub (5 tiles x 576)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None             # None = d_model // n_heads
    attention: Literal["gqa", "mla", "none"] = "gqa"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    causal: bool = True
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    enc_dec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    mtp: bool = False                       # DeepSeek-V3 multi-token prediction
    mtp_loss_weight: float = 0.3
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"        # "bfloat16" = compressed moments
    optimizer_factored: bool = False        # Adafactor-style factored 2nd moment
    grad_accum: int = 1                     # microbatch accumulation steps
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 512                   # kv-chunked attention block
    # which assigned shapes are skipped and why (DESIGN.md §Arch-applicability)
    skip_shapes: tuple[str, ...] = ()
    skip_reason: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def param_count(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts — used for MODEL_FLOPS."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attention == "mla":
            m = cfg.mla
            qin = m.q_lora_rank or d
            p = d * (m.kv_lora_rank + m.qk_rope_head_dim)          # down kv + rope
            p += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            if m.q_lora_rank:
                p += d * m.q_lora_rank
            p += qin * cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            p += cfg.n_heads * m.v_head_dim * d                    # o proj
            return p
        if cfg.attention == "none":
            return 0
        return d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d

    def ffn_params(ff):
        return 3 * d * ff                                          # SwiGLU

    total = emb
    active = emb
    if cfg.family == "ssm":
        if cfg.rwkv is not None:
            per_layer = 4 * d * d + 3 * d * d + int(2.1 * d * cfg.d_ff)  # wkv + ffn approx
        else:
            per_layer = 2 * d * (cfg.ssm.expand * d) + d * cfg.d_ff * 3
        total += L * per_layer
        active += L * per_layer
        return int(total), int(active)
    if cfg.family == "hybrid":
        s = cfg.ssm
        din = s.expand * d
        mamba = L * (2 * d * din + din * d + din * (2 * s.state_dim))
        n_shared_apps = L // max(s.shared_stride, 1) if s.shared_stride else 0
        shared = (d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
                  + ffn_params(s.shared_d_ff)) if n_shared_apps else 0
        total += mamba + shared
        active += mamba + shared * n_shared_apps  # shared weights reused
        return int(total), int(active)

    per_layer_attn = attn_params()
    if cfg.moe is not None:
        m = cfg.moe
        n_dense = m.first_dense_layers
        n_moe = L - n_dense
        shared_ff = m.shared_d_ff or m.n_shared * m.expert_d_ff
        dense_p = n_dense * (per_layer_attn + ffn_params(m.dense_d_ff))
        moe_total = n_moe * (per_layer_attn + ffn_params(shared_ff)
                             + m.n_experts * ffn_params(m.expert_d_ff) + d * m.n_experts)
        moe_active = n_moe * (per_layer_attn + ffn_params(shared_ff)
                              + m.top_k * ffn_params(m.expert_d_ff) + d * m.n_experts)
        total += dense_p + moe_total
        active += dense_p + moe_active
    else:
        if cfg.enc_dec is not None:
            enc = cfg.enc_dec.n_encoder_layers * (per_layer_attn + ffn_params(cfg.d_ff))
            dec = L * (2 * per_layer_attn + ffn_params(cfg.d_ff))  # self + cross
            total += enc + dec
            active += enc + dec
        else:
            total += L * (per_layer_attn + ffn_params(cfg.d_ff))
            active += L * (per_layer_attn + ffn_params(cfg.d_ff))
    return int(total), int(active)
