"""minitron-8b [arXiv:2407.14679]: width-pruned Nemotron-4, dense GQA.

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=16384, vocab=256000.
long_500k skipped (full attention).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron_8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention decoder; 500k decode needs sub-quadratic attention",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512,
    )
