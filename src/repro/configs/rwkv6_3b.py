"""rwkv6-3b "Finch" [arXiv:2404.05892]: attention-free, data-dependent decay.

32L, d_model=2560, d_ff=8960, vocab=65536, head_dim=64 (40 heads).
Runs long_500k: decode is O(1)-state recurrence, no KV cache at all.
"""
import dataclasses

from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6_3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    attention="none",
    rwkv=RWKVConfig(head_dim=64, wkv_mode="chunked"),
    # §Perf note: grad_accum=8 was tried and REFUTED — accumulation splits
    # peak memory, not traffic, and re-gathers params per microbatch
    # (262s -> 424s memory term); see EXPERIMENTS.md §Perf.
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, rwkv=RWKVConfig(head_dim=16),
    )
