"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, param_count

ARCH_IDS = [
    "whisper_tiny",
    "deepseek_v2_lite_16b",
    "deepseek_v3_671b",
    "zamba2_2p7b",
    "llava_next_34b",
    "minitron_8b",
    "llama3_405b",
    "deepseek_7b",
    "phi4_mini_3p8b",
    "rwkv6_3b",
]

# cli aliases with dashes/dots
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_config(name: str) -> ArchConfig:
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch '{name}'; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def get_reduced_config(name: str) -> ArchConfig:
    name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.reduced()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config",
           "get_reduced_config", "all_configs", "param_count"]
