"""whisper-tiny [arXiv:2212.04356]: enc-dec audio transformer.

4L decoder (+4L encoder), d_model=384, 6 heads (kv=6 -> MHA), d_ff=1536,
vocab=51865.  Conv frontend is a STUB per assignment: input_specs provide
precomputed frame embeddings [B, 1500, 384].  long_500k skipped (pure full
attention, DESIGN.md §Arch-applicability).
"""
import dataclasses

from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper_tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    attention="gqa",
    tie_embeddings=True,   # whisper ties the decoder embedding
    enc_dec=EncDecConfig(n_encoder_layers=4, n_frames=1500),
    causal=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention enc-dec; 500k decode needs sub-quadratic attention",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512,
        enc_dec=EncDecConfig(n_encoder_layers=2, n_frames=16),
    )
