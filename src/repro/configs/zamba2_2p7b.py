"""zamba2-2.7b [arXiv:2411.15242]: Mamba2 backbone + shared attention block.

54 Mamba2 layers, d_model=2560, ssm_state=64; one *shared* attention+MLP
block (32 heads, d_ff=10240) applied every 6 layers (weights reused — the
Zamba trick).  Sub-quadratic: runs long_500k (shared-block KV caches are
sequence-sharded over the data axis for decode).
"""
import dataclasses

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2_2p7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk=128, shared_stride=6, shared_d_ff=10240),
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512,
        ssm=SSMConfig(state_dim=8, head_dim=16, expand=2, conv_width=4,
                      chunk=16, shared_stride=2, shared_d_ff=128),
    )
