"""llama3-405b [arXiv:2407.21783]: dense GQA at maximum assigned scale.

126L, d_model=16384, 128 heads (GQA kv=8), d_ff=53248, vocab=128256,
rope theta 500k.  bf16 optimizer moments (memory compression) so the
train_4k cell fits the 256-chip v5e pod.  long_500k skipped (full attn).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    param_dtype="bfloat16",       # + bf16 m + factored v: ~4.5 B/param state
    optimizer_dtype="bfloat16",
    optimizer_factored=True,
    grad_accum=16,                # 1M-token batch in 16 microbatches
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention decoder; 500k decode needs sub-quadratic attention",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512, param_dtype="float32", optimizer_dtype="float32",
    )
