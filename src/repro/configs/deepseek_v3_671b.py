"""deepseek-v3-671b [arXiv:2412.19437]: MLA + 256-expert MoE + MTP.

61L, d_model=7168, 128 heads, vocab=129280; MLA kv_lora=512, q_lora=1536;
MoE: 256 routed top-8 (sigmoid router with aux-free bias, normalized top-k
probs) + 1 shared, expert d_ff=2048, first 3 layers dense (d_ff=18432);
multi-token prediction (depth-1 MTP module).
"""
import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek_v3_671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, expert_d_ff=2048,
                  first_dense_layers=3, dense_d_ff=18432,
                  router="sigmoid_bias", norm_topk_prob=True),
    mtp=True,
    param_dtype="bfloat16",
    optimizer_dtype="bfloat16",   # moment compression for the 671B cell
    optimizer_factored=True,
    grad_accum=8,
    skip_shapes=("long_500k",),
    skip_reason="full (latent) attention over the sequence; 500k decode skipped",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, d_ff=96,
        vocab_size=512, mtp=True,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, expert_d_ff=32,
                      first_dense_layers=1, dense_d_ff=96,
                      router="sigmoid_bias", norm_topk_prob=True),
    )
