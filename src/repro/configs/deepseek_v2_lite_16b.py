"""deepseek-v2-lite-16b [arXiv:2405.04434]: MLA + fine-grained MoE.

27L, d_model=2048, 16 heads, vocab=102400; MLA kv_lora=512 (rope 64/nope 128,
v 128); MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, first
layer dense (d_ff=10944).  NOTE: the assignment line lists both "64e top-6"
and "160 routed"; the published v2-lite config is 64 routed experts — we
follow the published card and the "64e top-6" reading.
"""
import dataclasses

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite_16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, expert_d_ff=1408,
                  first_dense_layers=1, dense_d_ff=10944, router="softmax"),
    skip_shapes=("long_500k",),
    skip_reason="full (latent) attention over the sequence; 500k decode skipped",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, d_ff=96,
        vocab_size=512,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=None,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, expert_d_ff=32,
                      first_dense_layers=1, dense_d_ff=96, router="softmax"),
    )
