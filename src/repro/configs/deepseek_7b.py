"""deepseek-7b [arXiv:2401.02954]: llama-architecture dense MHA.

30L, d_model=4096, 32 heads (kv=32 -> MHA), d_ff=11008, vocab=102400.
long_500k skipped (full attention).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    head_dim=128,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention decoder; 500k decode needs sub-quadratic attention",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192, vocab_size=512,
    )
