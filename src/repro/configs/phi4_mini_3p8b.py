"""phi4-mini-3.8b [arXiv:2412.08905]: dense GQA, huge vocab.

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=200064.
long_500k skipped (full attention).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4_mini_3p8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    head_dim=128,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention decoder; 500k decode needs sub-quadratic attention",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=512,
    )
