"""llava-next-34b [hf:llava-hf/llava-v1.6]: VLM — dense decoder backbone.

60L, d_model=7168, 56 heads (GQA kv=8), d_ff=20480, vocab=64000 (Yi-34B-class
backbone).  The anyres vision tower is a STUB per assignment: input_specs
provide pre-projected patch embeddings [B, 2880, d_model] prepended to the
text sequence; loss masks patch positions.  long_500k skipped (full attn).
"""
import dataclasses

from repro.configs.base import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="llava_next_34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5000000.0,
    vlm=VLMConfig(n_patches=2880),
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention decoder; 500k decode needs sub-quadratic attention",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vlm=VLMConfig(n_patches=8),
    )
