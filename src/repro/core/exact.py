"""Exact O(N^2) t-SNE quantities — the correctness oracle for every
approximated step (Barnes-Hut repulsion, sparse attraction, KL estimate)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def exact_repulsion(y: jax.Array):
    """Returns (force_unnorm [N,2], Z) with
    force_unnorm_i = sum_{j!=i} (1+d^2)^-2 (y_i - y_j),  Z = sum_{k!=l} (1+d^2)^-1."""
    diff = y[:, None, :] - y[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    w = 1.0 / (1.0 + d2)
    w = w - jnp.diag(jnp.diag(w))          # zero self terms
    z = jnp.sum(w)
    force = jnp.sum((w * w)[..., None] * diff, axis=1)
    return force, z


def exact_attraction(y: jax.Array, p_dense: jax.Array):
    """force_i = sum_j p_ij (1+d^2)^-1 (y_i - y_j); also attractive KL part."""
    diff = y[:, None, :] - y[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = p_dense / (1.0 + d2)
    force = jnp.sum(pq[..., None] * diff, axis=1)
    kl_attr = jnp.sum(p_dense * jnp.log1p(d2))
    return force, kl_attr


def exact_gradient(y: jax.Array, p_dense: jax.Array, exaggeration: float = 1.0):
    """dC/dy (eq. 6/7): 4 * (exag * F_attr - F_rep / Z)."""
    fa, _ = exact_attraction(y, p_dense)
    fr, z = exact_repulsion(y)
    return 4.0 * (exaggeration * fa - fr / z)


def exact_kl(y: jax.Array, p_dense: jax.Array):
    """KL(P||Q) with Q the normalized Student-t similarities of y."""
    diff = y[:, None, :] - y[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    w = 1.0 / (1.0 + d2)
    w = w - jnp.diag(jnp.diag(w))
    q = w / jnp.sum(w)
    p = p_dense
    mask = p > 0
    return jnp.sum(jnp.where(mask, p * (jnp.log(jnp.maximum(p, 1e-30)) - jnp.log(jnp.maximum(q, 1e-30))), 0.0))
