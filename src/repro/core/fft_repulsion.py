"""FIt-SNE-style FFT-accelerated repulsion (Linderman et al., 2019).

The paper benchmarks Acc-t-SNE against FIt-SNE (its strongest competitor on
one thread — paper Table 4), so the baseline is implemented too: polynomial
interpolation onto a regular grid, kernel convolution via FFT (circulant
embedding), and interpolation back:

    phi_k(x_i) ~= sum_(p^2 nodes) L_p(x_i) * (K * spread(charges))[node]

Charges {1, y_x, y_y} against K2 = (1+d^2)^-2 give the repulsive numerator;
charge {1} against K1 = (1+d^2)^-1 gives Z.  O(N p^2 + M^2 log M) per
iteration instead of O(N log N) BH traversal.  Accuracy is controlled by
the node count (tests: ~1% force error at 128 nodes/dim vs exact O(N^2)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

P_ORDER = 3  # interpolation nodes per box per dim (cubic-ish accuracy)


def _lagrange_weights(frac: jax.Array) -> jax.Array:
    """Weights of the 3 equispaced nodes {0, .5, 1} for position frac [N]."""
    t = frac
    w0 = 2.0 * (t - 0.5) * (t - 1.0)
    w1 = -4.0 * t * (t - 1.0)
    w2 = 2.0 * t * (t - 0.5)
    return jnp.stack([w0, w1, w2], axis=-1)  # [N, 3]


@functools.partial(jax.jit, static_argnames=("n_boxes",))
def fft_repulsion(y: jax.Array, n_boxes: int = 48):
    """Returns (force_unnorm [N,2], z) matching exact_repulsion's contract."""
    n = y.shape[0]
    dtype = y.dtype
    lo = jnp.min(y, axis=0) - 1e-4
    hi = jnp.max(y, axis=0) + 1e-4
    span = jnp.maximum(jnp.max(hi - lo), 1e-12)
    # nodes per dim: boxes * (P-1) + 1 interior lattice, embedded to M
    m = n_boxes * (P_ORDER - 1)
    h = span / m
    # fractional lattice coordinates
    u = (y - lo[None, :]) / h                              # in [0, m)
    iu = jnp.clip(jnp.floor(u / (P_ORDER - 1)).astype(jnp.int32), 0, n_boxes - 1)
    base = iu * (P_ORDER - 1)                              # box start node
    frac = (u - base) / (P_ORDER - 1)                      # [N,2] in [0,1]
    wx = _lagrange_weights(frac[:, 0])                     # [N,3]
    wy = _lagrange_weights(frac[:, 1])

    # spread charges {1, yx, yy} onto the (m+1)^2 node lattice
    charges = jnp.stack([jnp.ones((n,), dtype), y[:, 0], y[:, 1]], axis=1)
    nodes = m + 1
    gx = base[:, 0, None] + jnp.arange(P_ORDER)[None, :]   # [N,3]
    gy = base[:, 1, None] + jnp.arange(P_ORDER)[None, :]
    w2d = wx[:, :, None] * wy[:, None, :]                  # [N,3,3]
    flat_idx = (gx[:, :, None] * nodes + gy[:, None, :]).reshape(n, -1)
    contrib = (w2d.reshape(n, -1)[:, :, None] * charges[:, None, :])  # [N,9,3]
    grid = jnp.zeros((nodes * nodes, 3), dtype)
    grid = grid.at[flat_idx.reshape(-1)].add(contrib.reshape(-1, 3))
    grid = grid.reshape(nodes, nodes, 3)

    # kernel convolution via circulant embedding (size 2*nodes)
    big = 2 * nodes
    dx = jnp.minimum(jnp.arange(big), big - jnp.arange(big)).astype(dtype) * h
    d2 = dx[:, None] ** 2 + dx[None, :] ** 2
    k1 = 1.0 / (1.0 + d2)
    k2 = k1 * k1
    fk1 = jnp.fft.rfft2(k1)
    fk2 = jnp.fft.rfft2(k2)
    gpad = jnp.pad(grid, ((0, big - nodes), (0, big - nodes), (0, 0)))
    fg = jnp.fft.rfft2(gpad, axes=(0, 1))
    pot2 = jnp.fft.irfft2(fg * fk2[:, :, None], s=(big, big), axes=(0, 1))[:nodes, :nodes]
    pot1 = jnp.fft.irfft2(fg[..., 0] * fk1, s=(big, big))[:nodes, :nodes]

    # gather potentials back at the points
    def gather(pot):
        vals = pot.reshape(-1)[flat_idx]                   # [N,9]
        return jnp.sum(vals * w2d.reshape(n, -1), axis=1)

    phi2_1 = gather(pot2[:, :, 0])                         # sum K2
    phi2_x = gather(pot2[:, :, 1])                         # sum K2*yx
    phi2_y = gather(pot2[:, :, 2])
    phi1_1 = gather(pot1)                                  # sum K1 (incl self)

    z = jnp.sum(phi1_1) - n                                # remove self terms
    fx = y[:, 0] * phi2_1 - phi2_x                         # self term cancels
    fy = y[:, 1] * phi2_1 - phi2_y
    return jnp.stack([fx, fy], axis=1), jnp.maximum(z, 1e-30)
