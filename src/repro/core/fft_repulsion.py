"""FIt-SNE-style FFT-accelerated repulsion (Linderman et al., 2019).

The paper benchmarks Acc-t-SNE against FIt-SNE (its strongest competitor on
one thread — paper Table 4), so the baseline is implemented too: polynomial
interpolation onto a regular grid, kernel convolution via FFT (circulant
embedding), and interpolation back:

    phi_k(x_i) ~= sum_(p^2 nodes) L_p(x_i) * (K * spread(charges))[node]

Charges {1, y_x, y_y} against K2 = (1+d^2)^-2 give the repulsive numerator;
charge {1} against K1 = (1+d^2)^-1 gives Z.  O(N p^2 + M^2 log M) per
iteration instead of O(N log N) BH traversal.  Accuracy is controlled by
the node count (tests: ~1% force error at 128 nodes/dim vs exact O(N^2)).

The interpolation scatter/gather — the O(N p^2) half, which dominates once
N >> nodes^2 — is split into :func:`spread_to_grid` / :func:`gather_from_grid`
so it can dispatch to the Pallas tile kernels in ``kernels/interp_kernel.py``
(``interp_impl="pallas"``; registered as ``fft_spread`` / ``fft_gather`` in
the ``kernels/ops`` registry).  The jnp functions here are the oracles those
kernels are parity-tested against.  The FFT itself stays in XLA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

P_ORDER = 3  # interpolation nodes per box per dim (cubic-ish accuracy)

# Hard cap on the boxes-per-dim grid resolution.  The Pallas interp kernels
# keep the whole [C, G, G] node lattice VMEM-resident per grid step, so the
# lattice (G = 2*n_boxes+1 padded to the 128-lane boundary) must stay inside
# the ~16 MB budget; `repro.analysis` certifies the BlockSpecs at exactly
# this envelope.  FIt-SNE-style accuracy needs ~50-100 boxes — 128 is head
# room, not a constraint.
MAX_N_BOXES = 128

INTERP_IMPLS = ("xla", "pallas")


def _lagrange_weights(frac: jax.Array) -> jax.Array:
    """Weights of the 3 equispaced nodes {0, .5, 1} for position frac [N]."""
    t = frac
    w0 = 2.0 * (t - 0.5) * (t - 1.0)
    w1 = -4.0 * t * (t - 1.0)
    w2 = 2.0 * t * (t - 0.5)
    return jnp.stack([w0, w1, w2], axis=-1)  # [N, 3]


def interp_coords(y: jax.Array, n_boxes: int):
    """Lattice geometry shared by spread and gather.

    Returns (base [N,2] int32 — the box-start node per dim, wx [N,3],
    wy [N,3] — per-dim Lagrange weights, h — node spacing).
    """
    lo = jnp.min(y, axis=0) - 1e-4
    hi = jnp.max(y, axis=0) + 1e-4
    span = jnp.maximum(jnp.max(hi - lo), 1e-12)
    m = n_boxes * (P_ORDER - 1)            # interior lattice nodes per dim
    h = span / m
    u = (y - lo[None, :]) / h              # fractional lattice coords in [0, m)
    iu = jnp.clip(jnp.floor(u / (P_ORDER - 1)).astype(jnp.int32), 0, n_boxes - 1)
    base = iu * (P_ORDER - 1)              # box start node
    frac = (u - base) / (P_ORDER - 1)      # [N,2] in [0,1]
    wx = _lagrange_weights(frac[:, 0])
    wy = _lagrange_weights(frac[:, 1])
    return base, wx, wy, h


def spread_to_grid(base, wx, wy, charges, nodes: int):
    """Scatter per-point charges onto the node lattice (jnp oracle).

    base [N,2] int32, wx/wy [N,3], charges [N,C] -> grid [nodes, nodes, C]:
    grid[a, b, c] = sum_i wx[i, a - base_x[i]] * wy[i, b - base_y[i]] * charges[i, c]
    (taps outside the 3x3 stencil contribute zero).
    """
    n, c = charges.shape
    gx = base[:, 0, None] + jnp.arange(P_ORDER)[None, :]   # [N,3]
    gy = base[:, 1, None] + jnp.arange(P_ORDER)[None, :]
    w2d = wx[:, :, None] * wy[:, None, :]                  # [N,3,3]
    flat_idx = (gx[:, :, None] * nodes + gy[:, None, :]).reshape(n, -1)
    contrib = w2d.reshape(n, -1)[:, :, None] * charges[:, None, :]  # [N,9,C]
    grid = jnp.zeros((nodes * nodes, c), charges.dtype)
    grid = grid.at[flat_idx.reshape(-1)].add(contrib.reshape(-1, c))
    return grid.reshape(nodes, nodes, c)


def gather_from_grid(pot, base, wx, wy):
    """Interpolate node potentials back at the points (jnp oracle).

    pot [nodes, nodes, C], base [N,2] int32, wx/wy [N,3] -> phi [N, C]:
    the transpose of :func:`spread_to_grid` with unit charges.
    """
    nodes, _, c = pot.shape
    n = base.shape[0]
    gx = base[:, 0, None] + jnp.arange(P_ORDER)[None, :]
    gy = base[:, 1, None] + jnp.arange(P_ORDER)[None, :]
    w2d = (wx[:, :, None] * wy[:, None, :]).reshape(n, -1)  # [N,9]
    flat_idx = (gx[:, :, None] * nodes + gy[:, None, :]).reshape(n, -1)
    vals = pot.reshape(-1, c)[flat_idx]                     # [N,9,C]
    return jnp.sum(vals * w2d[:, :, None], axis=1)          # [N,C]


@functools.partial(jax.jit, static_argnames=("n_boxes", "interp_impl"))
def fft_repulsion(y: jax.Array, n_boxes: int = 48, interp_impl: str = "xla"):
    """Returns (force_unnorm [N,2], z) matching exact_repulsion's contract.

    ``interp_impl`` selects the spread/gather implementation: "xla" (the jnp
    oracles above) or "pallas" (tiled one-hot-matmul kernels, interpret-mode
    on CPU).
    """
    if not 1 <= n_boxes <= MAX_N_BOXES:
        raise ValueError(
            f"n_boxes={n_boxes} outside [1, {MAX_N_BOXES}] — the interp "
            "kernels keep the whole node lattice VMEM-resident (MAX_N_BOXES)"
        )
    if interp_impl == "pallas":
        from repro.kernels.ops import fft_gather, fft_spread
        spread, gather = fft_spread, fft_gather
    elif interp_impl == "xla":
        spread, gather = spread_to_grid, gather_from_grid
    else:
        raise ValueError(
            f"unknown interp impl {interp_impl!r} "
            f"(known: {', '.join(INTERP_IMPLS)})"
        )
    n = y.shape[0]
    dtype = y.dtype
    m = n_boxes * (P_ORDER - 1)
    nodes = m + 1
    base, wx, wy, h = interp_coords(y, n_boxes)

    # spread charges {1, yx, yy} onto the (m+1)^2 node lattice
    charges = jnp.stack([jnp.ones((n,), dtype), y[:, 0], y[:, 1]], axis=1)
    grid = spread(base, wx, wy, charges, nodes)            # [nodes, nodes, 3]

    # kernel convolution via circulant embedding (size 2*nodes)
    big = 2 * nodes
    dx = jnp.minimum(jnp.arange(big), big - jnp.arange(big)).astype(dtype) * h
    d2 = dx[:, None] ** 2 + dx[None, :] ** 2
    k1 = 1.0 / (1.0 + d2)
    k2 = k1 * k1
    fk1 = jnp.fft.rfft2(k1)
    fk2 = jnp.fft.rfft2(k2)
    gpad = jnp.pad(grid, ((0, big - nodes), (0, big - nodes), (0, 0)))
    fg = jnp.fft.rfft2(gpad, axes=(0, 1))
    pot2 = jnp.fft.irfft2(fg * fk2[:, :, None], s=(big, big), axes=(0, 1))[:nodes, :nodes]
    pot1 = jnp.fft.irfft2(fg[..., 0] * fk1, s=(big, big))[:nodes, :nodes]

    # gather all four potentials back at the points in one pass:
    # channels = {sum K2, sum K2*yx, sum K2*yy, sum K1 (incl self)}
    pot_all = jnp.concatenate([pot2, pot1[:, :, None]], axis=2)
    phi = gather(pot_all, base, wx, wy)                    # [N, 4]
    phi2_1, phi2_x, phi2_y, phi1_1 = (phi[:, 0], phi[:, 1], phi[:, 2], phi[:, 3])

    z = jnp.sum(phi1_1) - n                                # remove self terms
    fx = y[:, 0] * phi2_1 - phi2_x                         # self term cancels
    fy = y[:, 1] * phi2_1 - phi2_y
    return jnp.stack([fx, fy], axis=1), jnp.maximum(z, 1e-30)
