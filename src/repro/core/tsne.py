"""End-to-end Barnes-Hut t-SNE driver (paper Fig. 1a pipeline).

Pipeline:  KNN -> BSP -> symmetrize P -> gradient descent where every
iteration evaluates the attractive (sparse) + repulsive forces through a
pluggable :class:`~repro.api.backends.GradientBackend` (Barnes-Hut by
default), with early exaggeration, momentum switching and per-dimension
gains exactly as in the reference implementations the paper benchmarks
against (scikit-learn / daal4py).

The preprocessing product is a typed :class:`NeighborGraph` (a JAX pytree),
so the whole descent step — backend gradient + momentum/gains update — jits
as one program regardless of which backend is plugged in.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import attractive, bsp, morton, quadtree, similarity
from repro.core.summarize import summarize as _summarize
from repro.core.repulsive import bh_repulsion_sorted

# One count per distinct (embedding shape, backend, lr, min_gain) trace of
# the descent step — compile churn shows up in metric snapshots as
# ``recompiles.tsne_step`` instead of being invisible.
TSNE_STEP_RETRACES = obs.RecompileProbe("tsne_step")

# Single source of truth for the attractive-kernel variant ('blocked' is the
# cache-blocked Alg. 2 — the measured §Perf winner).  TsneConfig, bh_gradient
# and the barnes_hut backend all default to this constant.
DEFAULT_ATTRACTIVE_IMPL = "blocked"

# Hard cap on the resolved neighbor width K.  The ELL layouts and the Pallas
# tile budgets ([256, K] blocks resident in ~16 MB VMEM) are sized for this
# envelope, and `repro.analysis` certifies the kernel contracts exactly at
# it.  K = 3*perplexity, so this admits perplexity up to ~341 — far beyond
# any published t-SNE setting.
MAX_N_NEIGHBORS = 1024


@dataclasses.dataclass(frozen=True)
class TsneConfig:
    perplexity: float = 30.0
    n_iter: int = 1000
    theta: float = 0.5
    learning_rate: float | str = "auto"   # 'auto' = max(N / early_exaggeration, 50)
    early_exaggeration: float = 12.0
    exaggeration_iters: int = 250
    momentum_initial: float = 0.5
    momentum_final: float = 0.8
    momentum_switch_iter: int = 250
    min_gain: float = 0.01
    min_grad_norm: float = 1e-7           # early stop when ||grad|| drops below
    init_std: float = 1e-4
    depth: int | str = morton.DEFAULT_DEPTH   # "auto" = morton.auto_depth(N)
    seed: int = 0
    dtype: Any = jnp.float32
    n_neighbors: int | None = None        # None = int(3 * perplexity); clamped to n-1
    # registered neighbor backend ('exact' | 'rp_forest' | 'nn_descent' | ...)
    neighbor_method: str = "exact"
    # accepts a mapping; normalized to a sorted item tuple so the config
    # stays hashable (backends may embed it as a static jit argument)
    neighbor_options: Mapping[str, Any] | tuple | None = None
    knn_block_q: int = 512
    knn_block_db: int = 2048
    # rows per preprocessing slice: the BSP search and the ELL
    # symmetrization stream over [chunk_size, K] blocks instead of whole
    # [N, K] passes (None = unchunked).  The memory knob for million-point
    # runs — peak preprocessing transients are O(chunk_size * K).
    chunk_size: int | None = None
    # device count for the 'sharded' neighbor backend (None = all visible)
    knn_shards: int | None = None
    use_pallas: bool = False              # route hot loops through Pallas kernels
    # perplexity-search implementation: 'auto' follows use_pallas;
    # 'xla' | 'pallas' force one (core/bsp.py dispatch)
    bsp_impl: str = "auto"
    # FFT-repulsion spread/gather implementation, same semantics
    # (core/fft_repulsion.py dispatch, used by the 'fft' backend)
    fft_interp_impl: str = "auto"
    # 'blocked' (cache-blocked Alg.2 — default, §Perf winner) | 'ell'
    # (plain vectorized) | 'components' (SoA planes) | 'edges' (scatter)
    attractive_impl: str = DEFAULT_ATTRACTIVE_IMPL
    compress_tree: bool = True            # False = daal4py-like uncompressed tree
    method: str = "barnes_hut"            # registered gradient backend name
    fft_n_boxes: int = 48                 # grid boxes/dim for the 'fft' backend

    def __post_init__(self):
        if isinstance(self.neighbor_options, Mapping):
            object.__setattr__(
                self, "neighbor_options",
                tuple(sorted(self.neighbor_options.items())),
            )

    def resolve_lr(self, n: int) -> float:
        if self.learning_rate == "auto":
            return max(n / self.early_exaggeration, 50.0)
        return float(self.learning_rate)

    def resolve_n_neighbors(self, n: int) -> int:
        k = int(3.0 * self.perplexity) if self.n_neighbors is None \
            else int(self.n_neighbors)
        return max(1, min(k, n - 1, MAX_N_NEIGHBORS))

    def resolve_neighbor_options(self) -> dict:
        """Backend options with config-level defaults folded in."""
        opts = dict(self.neighbor_options or {})
        if self.neighbor_method == "exact":
            opts.setdefault("block_q", self.knn_block_q)
            opts.setdefault("block_db", self.knn_block_db)
            opts.setdefault("pairwise", "pallas" if self.use_pallas else "xla")
        elif self.neighbor_method in ("rp_forest", "nn_descent"):
            opts.setdefault("seed", self.seed)
        elif self.neighbor_method == "sharded":
            opts.setdefault("seed", self.seed)
            opts.setdefault("shards", self.knn_shards)
        return opts

    def resolve_chunk_size(self, n: int) -> int | None:
        """Preprocessing chunk: None = unchunked, else clamped to [1, n]."""
        if self.chunk_size is None:
            return None
        return max(1, min(int(self.chunk_size), n))

    def resolve_attractive_block(self) -> int:
        """Gradient-side attractive row block: never exceeds the configured
        preprocessing chunk, so one knob bounds live transients end-to-end
        (512 is the measured cache-resident default)."""
        if self.chunk_size is not None:
            return max(1, min(512, int(self.chunk_size)))
        return 512

    def resolve_depth(self, n: int) -> int:
        return morton.auto_depth(n) if self.depth == "auto" else int(self.depth)

    def resolve_bsp_impl(self) -> str:
        if self.bsp_impl == "auto":
            return "pallas" if self.use_pallas else "xla"
        return self.bsp_impl

    def resolve_fft_interp_impl(self) -> str:
        if self.fft_interp_impl == "auto":
            return "pallas" if self.use_pallas else "xla"
        return self.fft_interp_impl


class TsneState(NamedTuple):
    y: jax.Array
    velocity: jax.Array
    gains: jax.Array
    iteration: jax.Array


class GradResult(NamedTuple):
    """Common product of every gradient backend (exact / barnes_hut / fft)."""
    grad: jax.Array
    kl: jax.Array          # KL(P||Q) estimate (exact attractive part, backend Z)
    z: jax.Array
    max_traversal: jax.Array  # BH tree-walk depth; 0 for tree-free backends


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NeighborGraph:
    """Sparse symmetric input-similarity graph produced by :func:`preprocess`.

    A JAX pytree: flows straight through ``jax.jit`` as one operand, so any
    backend can pick whichever layout it needs (ELL rows or the directed edge
    list) inside a jitted step.
    """
    p_cols: jax.Array       # [N, W] int32 ELL neighbor indices (pad: row idx)
    p_vals: jax.Array       # [N, W] symmetric p_ij, sums to 1 (pad: 0)
    edge_src: jax.Array     # [NK] directed KNN edges ([1] dummy when unused)
    edge_dst: jax.Array
    edge_w: jax.Array       # p_{dst|src} / 2N
    p_logp: jax.Array       # exact sum_ij p_ij log p_ij (KL constant)
    n: int = dataclasses.field(metadata=dict(static=True), default=0)
    has_edges: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def edges(self) -> tuple[jax.Array, jax.Array, jax.Array] | None:
        return (self.edge_src, self.edge_dst, self.edge_w) if self.has_edges else None


def combine_forces(
    f_attr, kl_attr, f_rep_unnorm, z, exaggeration, p_logp,
    max_traversal=None,
) -> GradResult:
    """Shared backend epilogue (eq. 6/7): fold attractive + repulsive terms.

    grad = 4 (exag * F_attr - F_rep / Z);  KL = sum p log p + kl_attr + log Z.
    ``f_rep_unnorm`` is the un-normalized repulsive numerator.
    """
    dtype = f_attr.dtype
    z = jnp.maximum(z, 1e-30)
    grad = 4.0 * (jnp.asarray(exaggeration, dtype) * f_attr - f_rep_unnorm / z)
    kl = p_logp + kl_attr + jnp.log(z)
    if max_traversal is None:
        max_traversal = jnp.zeros((), jnp.int32)
    return GradResult(grad=grad, kl=kl, z=z, max_traversal=max_traversal)


# ---------------------------------------------------------------------------
# One BH gradient evaluation (steps 3-6 of Fig. 1a)
# ---------------------------------------------------------------------------

def bh_gradient(
    y: jax.Array,
    p_cols: jax.Array | None,
    p_vals: jax.Array | None,
    edges: tuple[jax.Array, jax.Array, jax.Array] | None,
    theta: float,
    exaggeration: jax.Array | float,
    depth: int,
    p_logp: jax.Array | float,
    compress_tree: bool = True,
    use_pallas: bool = False,
    attractive_impl: str = DEFAULT_ATTRACTIVE_IMPL,
    attractive_block: int = 512,
) -> GradResult:
    # --- quadtree building (step 3) ---
    cent, r_span = morton.span_radius(y)
    if use_pallas:
        from repro.kernels.ops import morton_encode as enc
        codes = enc(y, cent, r_span, depth=depth)
    else:
        codes = morton.morton_encode(y, cent, r_span, depth=depth)
    codes_s, y_s, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(codes_s, depth=depth, compress=compress_tree)
    # --- summarization (step 4) ---
    summ = _summarize(tree, y_s, r_span)
    # --- repulsive (step 6) ---
    rep = bh_repulsion_sorted(y_s, tree, summ, theta)
    z = jnp.sum(rep.z_per_point)
    f_rep = jnp.zeros_like(y).at[perm].set(rep.force)
    # --- attractive (step 5) ---
    if edges is not None:
        f_attr, kl_attr = attractive.attractive_forces_edges(y, *edges)
    else:
        if use_pallas:
            from repro.kernels.ops import attractive_forces_ell as attr_ell
        elif attractive_impl == "blocked":
            attr_ell = functools.partial(
                attractive.attractive_forces_ell_blocked,
                block=attractive_block,
            )
        else:
            attr_ell = attractive.ell_impl(attractive_impl)
        f_attr, kl_attr = attr_ell(y, p_cols, p_vals)
    return combine_forces(f_attr, kl_attr, f_rep, z, exaggeration, p_logp,
                          max_traversal=jnp.max(rep.steps))


# ---------------------------------------------------------------------------
# Gradient-descent update (momentum + gains, scikit-learn/daal4py-compatible)
# ---------------------------------------------------------------------------

def gd_update(state: TsneState, grad: jax.Array, lr: float, momentum, min_gain: float):
    same_sign = (grad > 0) == (state.velocity > 0)
    gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
    gains = jnp.maximum(gains, min_gain)
    velocity = momentum * state.velocity - lr * gains * grad
    y = state.y + velocity
    y = y - jnp.mean(y, axis=0, keepdims=True)
    return TsneState(y=y, velocity=velocity, gains=gains, iteration=state.iteration + 1)


class StepStats(NamedTuple):
    """Device-side per-iteration diagnostics returned by :func:`tsne_step`."""
    kl: jax.Array
    grad_norm: jax.Array
    z: jax.Array
    max_traversal: jax.Array


@functools.partial(jax.jit, static_argnames=("backend", "lr", "min_gain"))
def tsne_step(
    state: TsneState,
    graph: NeighborGraph,
    exaggeration,
    momentum,
    *,
    backend,
    lr: float,
    min_gain: float,
):
    """One descent iteration: backend gradient + momentum/gains update.

    ``backend`` is any hashable object with a
    ``gradient(y, graph, exaggeration) -> GradResult`` method (see
    ``repro.api.backends``); it is a static argument, so each backend
    compiles its own step program once.
    """
    TSNE_STEP_RETRACES.record(
        state.y.shape, type(backend).__name__, getattr(backend, "name", ""),
        lr, min_gain,
    )
    res = backend.gradient(state.y, graph, exaggeration)
    grad_norm = jnp.linalg.norm(res.grad)
    new_state = gd_update(state, res.grad, lr, momentum, min_gain)
    return new_state, StepStats(kl=res.kl, grad_norm=grad_norm, z=res.z,
                                max_traversal=res.max_traversal)


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------

class TsneResult(NamedTuple):
    y: np.ndarray
    kl: float
    kl_history: np.ndarray
    timings: dict
    n_iter: int = 0
    # the fitted sparse-P pytree (kept so estimators can persist / reuse the
    # neighbor structure without re-running KNN + perplexity search)
    graph: "NeighborGraph | None" = None


@dataclasses.dataclass(frozen=True)
class IterationStats:
    """Structured observer payload (replaces the bare ``(it, kl)`` callback)."""
    iteration: int          # 1-based iteration just completed
    kl: float               # KL(P||Q) estimate at this iteration
    grad_norm: float        # ||dC/dY||_F — drives min_grad_norm early stopping
    z: float                # repulsive normalizer estimate
    max_traversal: int      # deepest BH tree walk (0 for exact / fft backends)
    exaggeration: float
    momentum: float
    elapsed_s: float        # wall time since gradient descent started


ObserverFn = Callable[[IterationStats], None]


def preprocess(
    x: jax.Array, config: TsneConfig, tracer: obs.Tracer | None = None,
) -> tuple[NeighborGraph, dict]:
    """KNN + BSP + symmetrization -> (NeighborGraph, stage timings).

    The KNN stage dispatches through the ``repro.neighbors`` registry
    (``config.neighbor_method``); the timings dict records which backend ran
    (``neighbor_method``), the resolved ``n_neighbors``, and ``knn_mean_d2``
    — the mean selected squared distance, directly comparable against the
    exact backend's value on the same data as a recall proxy.

    With ``config.chunk_size`` set, the perplexity search and the ELL
    symmetrization stream over ``[chunk_size, K]`` row slices
    (``bsp.binary_search_perplexity_chunked`` /
    ``similarity.symmetrize_ell_chunked``) — numerically identical to the
    whole-array forms, with preprocessing transients bounded by the chunk
    instead of N.  Pair with ``neighbor_method="sharded"`` for the fully
    memory-bounded million-point pipeline (docs/ARCHITECTURE.md,
    "Scaling to 1M+").

    Each stage is a span on ``tracer`` (default: the process-global tracer)
    with ``block_until_ready`` sync at exit, and the per-stage seconds in
    the timings dict are those spans' durations — one timing source for
    both the Perfetto trace and ``timings_``.  When the tracer is disabled
    a private always-on tracer times the three phases (the spans are
    discarded with it), so timings stay populated at negligible cost.
    """
    from repro.neighbors import make_neighbor_backend  # lazy: builds on core
    if tracer is None:
        tracer = obs.get_tracer()
    timer = tracer if tracer.enabled else obs.Tracer()
    k = config.resolve_n_neighbors(int(x.shape[0]))
    nb = make_neighbor_backend(
        config.neighbor_method, config.resolve_neighbor_options()
    )
    with timer.span("knn", backend=nb.name, k=k, n=int(x.shape[0])) as sp_knn:
        idx, d2 = nb.neighbors(x.astype(config.dtype), k)
        sp_knn.sync((idx, d2))

    bsp_impl = config.resolve_bsp_impl()
    chunk = config.resolve_chunk_size(int(x.shape[0]))
    with timer.span("bsp", perplexity=config.perplexity, impl=bsp_impl,
                    chunk_size=chunk) as sp_bsp:
        if chunk is not None:
            cond_p, _ = bsp.binary_search_perplexity_chunked(
                d2, config.perplexity, chunk, impl=bsp_impl
            )
        else:
            cond_p, _ = bsp.binary_search_perplexity(
                d2, config.perplexity, impl=bsp_impl
            )
        sp_bsp.sync(cond_p)

    sp_sym_ctx = timer.span("symmetrize", layout=config.attractive_impl,
                            chunk_size=chunk)
    sp_sym = sp_sym_ctx.__enter__()
    n = int(x.shape[0])
    if config.attractive_impl == "edges":
        # edge layout: ship only the directed edge list ([N, W] ELL planes
        # would ride along as dead jit operands of every step).  The exact
        # KL constant comes from an ordered-pair dedup: mutual KNN edges sum
        # to the symmetric p_ij = (p_{j|i} + p_{i|j}) / 2N.
        src, dst, w = similarity.edge_list(idx, cond_p)
        s = np.asarray(src, np.int64)
        d = np.asarray(dst, np.int64)
        wv = np.asarray(w, np.float64)
        key = np.concatenate([s * n + d, d * n + s])
        val = np.concatenate([wv, wv])
        _, inv = np.unique(key, return_inverse=True)
        p = np.bincount(inv, weights=val)
        p = p / p.sum()
        p_logp = float((p[p > 0] * np.log(p[p > 0])).sum())
        has_edges = True
        p_cols = jnp.zeros((1, 1), jnp.int32)
        p_vals = jnp.zeros((1, 1), config.dtype)
    else:
        if chunk is not None:
            sym_cols, sym_vals = similarity.symmetrize_ell_chunked(
                idx, cond_p, chunk
            )
        else:
            sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        sym_vals = sym_vals / sym_vals.sum()
        pv = np.asarray(sym_vals)
        p_logp = float((pv[pv > 0] * np.log(pv[pv > 0])).sum())
        src = dst = jnp.zeros((1,), jnp.int32)
        w = jnp.zeros((1,), config.dtype)
        has_edges = False
        p_cols = jnp.asarray(sym_cols)
        p_vals = jnp.asarray(sym_vals, config.dtype)
    graph = NeighborGraph(
        p_cols=p_cols, p_vals=p_vals,
        edge_src=src, edge_dst=dst, edge_w=w,
        p_logp=jnp.asarray(p_logp, config.dtype),
        n=n,
        has_edges=has_edges,
    )
    sp_sym.sync((graph.p_vals, graph.edge_w))
    sp_sym_ctx.__exit__(None, None, None)
    return graph, dict(
        knn=sp_knn.duration_s, bsp=sp_bsp.duration_s,
        symmetrize=sp_sym.duration_s,
        neighbor_method=nb.name, n_neighbors=k,
        bsp_impl=bsp_impl,
        chunk_size=chunk,
        knn_mean_d2=float(jnp.mean(d2)),
    )


def init_state(n: int, config: TsneConfig) -> TsneState:
    key = jax.random.PRNGKey(config.seed)
    y0 = config.init_std * jax.random.normal(key, (n, 2), dtype=config.dtype)
    return TsneState(
        y=y0,
        velocity=jnp.zeros_like(y0),
        gains=jnp.ones_like(y0),
        iteration=jnp.zeros((), jnp.int32),
    )


def run_tsne(
    x,
    config: TsneConfig = TsneConfig(),
    observer: ObserverFn | None = None,
    kl_every: int = 50,
    backend=None,
    tracer: obs.Tracer | None = None,
    metrics: obs.MetricsRegistry | None = None,
) -> TsneResult:
    """Full t-SNE run through a pluggable gradient backend.

    ``backend`` defaults to the registered backend named ``config.method``;
    pass any ``GradientBackend`` instance to override.  ``observer`` is
    called with :class:`IterationStats` every ``kl_every`` iterations (and on
    the final one); ``config.min_grad_norm`` stops the descent early at those
    same checkpoints, matching scikit-learn's convergence rule.

    Observability: the run is one ``fit`` span with ``knn`` / ``bsp`` /
    ``symmetrize`` / ``gradient_descent`` children (the descent splits into
    ``early_exaggeration`` / ``main_phase``, with a zero-ish-width
    ``checkpoint`` span per KL evaluation carrying kl / grad-norm / mean
    gain), all on ``tracer`` — default the process-global one, a no-op
    unless enabled.  The returned ``timings`` dict is *derived from those
    spans*, so the Perfetto trace and ``timings_`` can never disagree.
    Checkpoint stats also land on ``metrics`` (default global registry) as
    ``fit.grad_norm`` / ``fit.gain_mean`` histograms and ``fit.kl`` gauge.
    """
    x = jnp.asarray(x, config.dtype)
    n = x.shape[0]
    lr = config.resolve_lr(n)
    if tracer is None:
        tracer = obs.get_tracer()
    if metrics is None:
        metrics = obs.get_metrics()
    timer = tracer if tracer.enabled else obs.Tracer()

    fit_ctx = timer.span("fit", n=int(n), method=config.method,
                         neighbor_method=config.neighbor_method)
    fit_ctx.__enter__()
    try:
        graph, timings = preprocess(x, config, tracer=timer)
        state = init_state(n, config)

        if backend is None:
            from repro.api.backends import make_backend  # lazy: api builds on core
            backend = make_backend(config.method, config, n)
        step_kw = dict(backend=backend, lr=lr, min_gain=config.min_gain)

        kl_hist = []
        gd_ctx = timer.span("gradient_descent", n_iter=config.n_iter, lr=lr)
        sp_gd = gd_ctx.__enter__()
        t0 = sp_gd.t0
        kl = float("nan")
        it = 0
        phase_name: str | None = None
        phase_ctx = phase_sp = None
        try:
            for it in range(config.n_iter):
                exag = config.early_exaggeration if it < config.exaggeration_iters else 1.0
                mom = config.momentum_initial if it < config.momentum_switch_iter else config.momentum_final
                want = "early_exaggeration" if it < config.exaggeration_iters \
                    else "main_phase"
                if want != phase_name:
                    if phase_ctx is not None:
                        phase_sp.sync(state.y)
                        phase_ctx.__exit__(None, None, None)
                    phase_ctx = timer.span(want, start_iter=it,
                                           exaggeration=exag)
                    phase_sp = phase_ctx.__enter__()
                    phase_name = want
                state, stats = tsne_step(
                    state, graph,
                    jnp.asarray(exag, config.dtype), jnp.asarray(mom, config.dtype),
                    **step_kw,
                )
                if (it + 1) % kl_every == 0 or it == config.n_iter - 1:
                    kl = float(stats.kl)
                    grad_norm = float(stats.grad_norm)
                    kl_hist.append((it + 1, kl))
                    metrics.histogram("fit.grad_norm").observe(grad_norm)
                    metrics.gauge("fit.kl").set(kl)
                    metrics.gauge("fit.exaggeration").set(exag)
                    if timer.enabled and timer is tracer:
                        # trace-only extras (one extra device pull)
                        gain_mean = float(jnp.mean(state.gains))
                        metrics.histogram("fit.gain_mean").observe(gain_mean)
                        with timer.span(
                            "checkpoint", iteration=it + 1, kl=kl,
                            grad_norm=grad_norm, z=float(stats.z),
                            exaggeration=exag, momentum=mom,
                            gain_mean=gain_mean,
                        ):
                            pass
                    if observer is not None:
                        observer(IterationStats(
                            iteration=it + 1, kl=kl, grad_norm=grad_norm,
                            z=float(stats.z), max_traversal=int(stats.max_traversal),
                            exaggeration=exag, momentum=mom,
                            elapsed_s=time.perf_counter() - t0,
                        ))
                    if grad_norm < config.min_grad_norm:
                        break
        finally:
            if phase_ctx is not None:
                phase_sp.sync(state.y)
                phase_ctx.__exit__(None, None, None)
            sp_gd.sync(state.y)
            gd_ctx.__exit__(None, None, None)
        timings["gradient_descent"] = sp_gd.duration_s
        metrics.counter("fit.iterations").inc(it + 1)
    finally:
        fit_ctx.__exit__(None, None, None)
    return TsneResult(
        y=np.asarray(state.y),
        kl=kl,
        kl_history=np.asarray(kl_hist, np.float64) if kl_hist else np.zeros((0, 2)),
        timings=timings,
        n_iter=it + 1,
        graph=graph,
    )
