"""End-to-end Barnes-Hut t-SNE driver (paper Fig. 1a pipeline).

Pipeline:  KNN -> BSP -> symmetrize P -> gradient descent where every
iteration rebuilds the Morton quadtree, summarizes it, and evaluates the
attractive (sparse) + repulsive (Barnes-Hut) forces, with early exaggeration,
momentum switching and per-dimension gains exactly as in the reference
implementations the paper benchmarks against (scikit-learn / daal4py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attractive, bsp, morton, quadtree, similarity
from repro.core.knn import knn as _knn
from repro.core.summarize import summarize as _summarize
from repro.core.repulsive import bh_repulsion_sorted


@dataclasses.dataclass(frozen=True)
class TsneConfig:
    perplexity: float = 30.0
    n_iter: int = 1000
    theta: float = 0.5
    learning_rate: float | str = "auto"   # 'auto' = max(N / early_exaggeration, 50)
    early_exaggeration: float = 12.0
    exaggeration_iters: int = 250
    momentum_initial: float = 0.5
    momentum_final: float = 0.8
    momentum_switch_iter: int = 250
    min_gain: float = 0.01
    init_std: float = 1e-4
    depth: int | str = morton.DEFAULT_DEPTH   # "auto" = morton.auto_depth(N)
    seed: int = 0
    dtype: Any = jnp.float32
    knn_block_q: int = 512
    knn_block_db: int = 2048
    use_pallas: bool = False              # route hot loops through Pallas kernels
    # 'blocked' (cache-blocked Alg.2 — default, §Perf winner) | 'ell'
    # (plain vectorized) | 'components' (SoA planes) | 'edges' (scatter)
    attractive_impl: str = "blocked"
    compress_tree: bool = True            # False = daal4py-like uncompressed tree

    def resolve_lr(self, n: int) -> float:
        if self.learning_rate == "auto":
            return max(n / self.early_exaggeration, 50.0)
        return float(self.learning_rate)

    def n_neighbors(self) -> int:
        return int(3.0 * self.perplexity)


class TsneState(NamedTuple):
    y: jax.Array
    velocity: jax.Array
    gains: jax.Array
    iteration: jax.Array


class GradResult(NamedTuple):
    grad: jax.Array
    kl: jax.Array          # KL(P||Q) estimate (exact attractive part, BH Z)
    z: jax.Array
    max_traversal: jax.Array


# ---------------------------------------------------------------------------
# One BH gradient evaluation (steps 3-6 of Fig. 1a)
# ---------------------------------------------------------------------------

def bh_gradient(
    y: jax.Array,
    p_cols: jax.Array | None,
    p_vals: jax.Array | None,
    edges: tuple[jax.Array, jax.Array, jax.Array] | None,
    theta: float,
    exaggeration: jax.Array | float,
    depth: int,
    p_logp: jax.Array | float,
    compress_tree: bool = True,
    use_pallas: bool = False,
    attractive_impl: str = "ell",
) -> GradResult:
    dtype = y.dtype
    # --- quadtree building (step 3) ---
    cent, r_span = morton.span_radius(y)
    if use_pallas:
        from repro.kernels.ops import morton_encode as enc
        codes = enc(y, cent, r_span, depth=depth)
    else:
        codes = morton.morton_encode(y, cent, r_span, depth=depth)
    codes_s, y_s, perm = quadtree.sort_points_by_code(y, codes)
    tree = quadtree.build_quadtree(codes_s, depth=depth, compress=compress_tree)
    # --- summarization (step 4) ---
    summ = _summarize(tree, y_s, r_span)
    # --- repulsive (step 6) ---
    rep = bh_repulsion_sorted(y_s, tree, summ, theta)
    z = jnp.maximum(jnp.sum(rep.z_per_point), 1e-30)
    f_rep = jnp.zeros_like(y).at[perm].set(rep.force) / z
    # --- attractive (step 5) ---
    if edges is not None:
        f_attr, kl_attr = attractive.attractive_forces_edges(y, *edges)
    else:
        if use_pallas:
            from repro.kernels.ops import attractive_forces_ell as attr_ell
        elif attractive_impl == "components":
            attr_ell = attractive.attractive_forces_ell_components
        elif attractive_impl == "blocked":
            attr_ell = attractive.attractive_forces_ell_blocked
        else:
            attr_ell = attractive.attractive_forces_ell
        f_attr, kl_attr = attr_ell(y, p_cols, p_vals)
    grad = 4.0 * (jnp.asarray(exaggeration, dtype) * f_attr - f_rep)
    kl = p_logp + kl_attr + jnp.log(z)
    return GradResult(grad=grad, kl=kl, z=z, max_traversal=jnp.max(rep.steps))


# ---------------------------------------------------------------------------
# Gradient-descent update (momentum + gains, scikit-learn/daal4py-compatible)
# ---------------------------------------------------------------------------

def gd_update(state: TsneState, grad: jax.Array, lr: float, momentum, min_gain: float):
    same_sign = (grad > 0) == (state.velocity > 0)
    gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
    gains = jnp.maximum(gains, min_gain)
    velocity = momentum * state.velocity - lr * gains * grad
    y = state.y + velocity
    y = y - jnp.mean(y, axis=0, keepdims=True)
    return TsneState(y=y, velocity=velocity, gains=gains, iteration=state.iteration + 1)


@functools.partial(
    jax.jit,
    static_argnames=("theta", "depth", "lr", "min_gain", "compress_tree",
                     "use_pallas", "has_edges", "attractive_impl"),
)
def tsne_step(
    state: TsneState,
    p_cols,
    p_vals,
    edge_src,
    edge_dst,
    edge_w,
    exaggeration,
    momentum,
    p_logp,
    *,
    theta: float,
    depth: int,
    lr: float,
    min_gain: float,
    compress_tree: bool,
    use_pallas: bool,
    has_edges: bool,
    attractive_impl: str = "ell",
):
    edges = (edge_src, edge_dst, edge_w) if has_edges else None
    res = bh_gradient(
        state.y, p_cols, p_vals, edges, theta, exaggeration, depth, p_logp,
        compress_tree=compress_tree, use_pallas=use_pallas,
        attractive_impl=attractive_impl,
    )
    new_state = gd_update(state, res.grad, lr, momentum, min_gain)
    return new_state, res.kl, res.max_traversal


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------

class TsneResult(NamedTuple):
    y: np.ndarray
    kl: float
    kl_history: np.ndarray
    timings: dict


def preprocess(x: jax.Array, config: TsneConfig):
    """KNN + BSP + symmetrization; returns the sparse-P operands."""
    k = config.n_neighbors()
    t0 = time.perf_counter()
    idx, d2 = _knn(
        x.astype(config.dtype), k,
        block_q=config.knn_block_q, block_db=config.knn_block_db,
        pairwise_fn_name="pallas" if config.use_pallas else "xla",
    )
    idx.block_until_ready()
    t_knn = time.perf_counter() - t0

    t0 = time.perf_counter()
    cond_p, _ = bsp.binary_search_perplexity(d2, config.perplexity)
    cond_p.block_until_ready()
    t_bsp = time.perf_counter() - t0

    t0 = time.perf_counter()
    if config.attractive_impl == "edges":
        src, dst, w = similarity.edge_list(idx, cond_p)
        operands = dict(edges=(src, dst, w), p_cols=None, p_vals=None)
        total_p = 2.0 * jnp.sum(w)
        w_sym = jnp.concatenate([w, w]) / total_p * 2.0  # ordered-pair weights
        p_logp = jnp.sum(jnp.where(w > 0, 2 * (w / total_p) * jnp.log(jnp.maximum(w / total_p, 1e-30)), 0.0))
        # note: edge-list p_logp is approximate when mutual edges overlap; the
        # exact Sum p log p only shifts KL by a constant — forces unaffected.
    else:
        sym_cols, sym_vals = similarity.symmetrize_ell(idx, cond_p)
        sym_vals = sym_vals / sym_vals.sum()
        p_cols = jnp.asarray(sym_cols)
        p_vals = jnp.asarray(sym_vals, config.dtype)
        operands = dict(edges=None, p_cols=p_cols, p_vals=p_vals)
        pv = np.asarray(sym_vals)
        p_logp = float((pv[pv > 0] * np.log(pv[pv > 0])).sum())
    t_sym = time.perf_counter() - t0
    return operands, jnp.asarray(p_logp, config.dtype), dict(knn=t_knn, bsp=t_bsp, symmetrize=t_sym)


def init_state(n: int, config: TsneConfig) -> TsneState:
    key = jax.random.PRNGKey(config.seed)
    y0 = config.init_std * jax.random.normal(key, (n, 2), dtype=config.dtype)
    return TsneState(
        y=y0,
        velocity=jnp.zeros_like(y0),
        gains=jnp.ones_like(y0),
        iteration=jnp.zeros((), jnp.int32),
    )


def run_tsne(
    x,
    config: TsneConfig = TsneConfig(),
    callback: Callable[[int, float], None] | None = None,
    kl_every: int = 50,
) -> TsneResult:
    x = jnp.asarray(x, config.dtype)
    n = x.shape[0]
    lr = config.resolve_lr(n)
    operands, p_logp, timings = preprocess(x, config)
    state = init_state(n, config)

    has_edges = operands["edges"] is not None
    e = operands["edges"] or (jnp.zeros((1,), jnp.int32),) * 2 + (jnp.zeros((1,), config.dtype),)
    depth = morton.auto_depth(n) if config.depth == "auto" else config.depth
    step_kw = dict(
        theta=config.theta, depth=depth, lr=lr, min_gain=config.min_gain,
        compress_tree=config.compress_tree, use_pallas=config.use_pallas,
        has_edges=has_edges, attractive_impl=config.attractive_impl,
    )
    kl_hist = []
    t0 = time.perf_counter()
    kl = jnp.asarray(jnp.nan)
    for it in range(config.n_iter):
        exag = config.early_exaggeration if it < config.exaggeration_iters else 1.0
        mom = config.momentum_initial if it < config.momentum_switch_iter else config.momentum_final
        state, kl, _ = tsne_step(
            state, operands["p_cols"], operands["p_vals"], e[0], e[1], e[2],
            jnp.asarray(exag, config.dtype), jnp.asarray(mom, config.dtype), p_logp,
            **step_kw,
        )
        if (it + 1) % kl_every == 0 or it == config.n_iter - 1:
            kl_val = float(kl)
            kl_hist.append((it + 1, kl_val))
            if callback is not None:
                callback(it + 1, kl_val)
    state.y.block_until_ready()
    timings["gradient_descent"] = time.perf_counter() - t0
    return TsneResult(
        y=np.asarray(state.y),
        kl=float(kl),
        kl_history=np.asarray(kl_hist, np.float64) if kl_hist else np.zeros((0, 2)),
        timings=timings,
    )
