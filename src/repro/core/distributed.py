"""Distributed Barnes-Hut t-SNE (shard_map) + ring KNN.

Distribution strategy (DESIGN.md §5): *points are sharded, the tree is
replicated*.  Y is tiny (N x 2) next to the per-point work, so every shard
all-gathers the embedding, rebuilds the (identical) Morton quadtree, and
traverses only its own point slice — the multi-device generalization of the
paper's thread-parallel repulsion, with the same attractive/BSP row
parallelism.  Z and the KL terms are psum'd.

The KNN is a collective_permute ring: each shard keeps its query slice and
streams database shards around the ring, merging running top-k per hop —
the transfer of hop t+1 overlaps the distance matmul of hop t.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import attractive, morton, quadtree
from repro.core._pairwise import pairwise_sq_dists
from repro.core.repulsive import bh_repulsion_sorted
from repro.core.summarize import summarize
from repro.core.tsne import GradResult


def _local_bh_gradient(y_loc, p_cols, p_vals, p_logp, *, axis, theta, exaggeration, depth):
    """shard_map body: y_loc [n_loc, 2]; P rows for the local points."""
    n_loc = y_loc.shape[0]
    rank = jax.lax.axis_index(axis)
    y_full = jax.lax.all_gather(y_loc, axis, tiled=True)          # [N, 2]
    n = y_full.shape[0]

    # replicated tree build (steps 3-4)
    cent, r_span = morton.span_radius(y_full)
    codes = morton.morton_encode(y_full, cent, r_span, depth=depth)
    codes_s, y_s, perm = quadtree.sort_points_by_code(y_full, codes)
    tree = quadtree.build_quadtree(codes_s, depth=depth)
    summ = summarize(tree, y_s, r_span)

    # local slice of sorted positions (inverse permutation of our indices)
    inv = jnp.zeros((n,), jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))
    my_pos = inv[rank * n_loc + jnp.arange(n_loc, dtype=jnp.int32)]

    # repulsion for local points only (step 6)
    theta2 = jnp.asarray(theta, y_loc.dtype) ** 2
    n_nodes = tree.n_nodes
    cap = tree.capacity
    is_leaf = tree.is_leaf

    def traverse(p, yp):
        def cond(state):
            return state[0] < n_nodes

        def body(state):
            ptr, force, z = state
            kk = jnp.minimum(ptr, cap - 1)
            s, e = tree.start[kk], tree.end[kk]
            cnt = summ.count[kk]
            inside = (s <= p) & (p < e)
            cnt_eff = cnt - jnp.where(inside, 1.0, 0.0)
            sum_eff = summ.sum_y[kk] - jnp.where(inside, yp, jnp.zeros_like(yp))
            com = sum_eff / jnp.maximum(cnt_eff, 1.0)
            diff = yp - com
            d2 = jnp.sum(diff * diff)
            side = summ.side[kk]
            open_ = (~is_leaf[kk]) & (side * side >= theta2 * d2)
            w = jnp.where(open_, 0.0, cnt_eff)
            q = 1.0 / (1.0 + d2)
            return (jnp.where(open_, ptr + 1, tree.skip[kk]),
                    force + (w * q * q) * diff, z + w * q)

        init = (jnp.int32(0), jnp.zeros((2,), y_loc.dtype), jnp.asarray(0.0, y_loc.dtype))
        _, force, z = jax.lax.while_loop(cond, body, init)
        return force, z

    f_rep, z_loc = jax.vmap(traverse)(my_pos, y_loc)
    z = jnp.maximum(jax.lax.psum(jnp.sum(z_loc), axis), 1e-30)

    # attractive for local rows (step 5) — cols are global indices
    yj = y_full[p_cols]
    diff = y_loc[:, None, :] - yj
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = p_vals / (1.0 + d2)
    f_attr = jnp.sum(pq[..., None] * diff, axis=1)
    kl_attr = jax.lax.psum(jnp.sum(p_vals * jnp.log1p(d2)), axis)

    grad = 4.0 * (jnp.asarray(exaggeration, y_loc.dtype) * f_attr - f_rep / z)
    kl = p_logp + kl_attr + jnp.log(z)
    return grad, kl, z


def distributed_bh_gradient(mesh, y, p_cols, p_vals, p_logp, *,
                            theta: float, exaggeration: float, depth: int = 16,
                            axis: str = "data") -> GradResult:
    """y [N,2] / p_cols, p_vals [N,K] sharded over ``axis`` (row-wise)."""
    fn = functools.partial(_local_bh_gradient, axis=axis, theta=theta,
                           exaggeration=exaggeration, depth=depth)
    grad, kl, z = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=(P(axis), P(), P()),
        check_vma=False,
    )(y, p_cols, p_vals, p_logp)
    return GradResult(grad=grad, kl=kl, z=z, max_traversal=jnp.int32(0))


# ---------------------------------------------------------------------------
# ring KNN
# ---------------------------------------------------------------------------

def ring_knn(mesh, x, k: int, axis: str = "data"):
    """Exact distributed KNN: x [N, D] sharded row-wise over ``axis``.

    Returns (idx [N,k] int32 global indices, d2 [N,k]), sharded like x.
    Each hop overlaps the next shard transfer (collective_permute) with the
    current distance tile (MXU matmul + top-k merge).
    """
    n_dev = mesh.shape[axis]

    def body(xq):
        n_loc = xq.shape[0]
        rank = jax.lax.axis_index(axis)
        big = jnp.asarray(jnp.finfo(xq.dtype).max, xq.dtype)
        q_idx = rank * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def hop(carry, t):
            chunk, owner, best_d, best_i = carry
            # kick off the next transfer, then compute on the current chunk
            nxt = jax.lax.ppermute(chunk, axis, perm)
            nxt_owner = (owner - 1) % n_dev
            d2 = pairwise_sq_dists(xq, chunk)
            col = owner * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
            d2 = jnp.where(col[None, :] == q_idx[:, None], big, d2)
            cat_d = jnp.concatenate([best_d, d2], axis=1)
            cat_i = jnp.concatenate(
                [best_i, jnp.broadcast_to(col[None, :], d2.shape)], axis=1)
            neg, arg = jax.lax.top_k(-cat_d, k)
            return (nxt, nxt_owner, -neg, jnp.take_along_axis(cat_i, arg, axis=1)), None

        init = (xq, rank, jnp.full((n_loc, k), big, xq.dtype),
                jnp.full((n_loc, k), -1, jnp.int32))
        (chunk, _, best_d, best_i), _ = jax.lax.scan(hop, init, jnp.arange(n_dev))
        return best_i, jnp.maximum(best_d, 0.0)

    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=(P(axis), P(axis)), check_vma=False)(x)
