"""Distributed Barnes-Hut t-SNE (shard_map) + ring KNN.

Distribution strategy (DESIGN.md §5): *points are sharded, the tree is
replicated*.  Y is tiny (N x 2) next to the per-point work, so every shard
all-gathers the embedding, rebuilds the (identical) Morton quadtree, and
traverses only its own point slice — the multi-device generalization of the
paper's thread-parallel repulsion, with the same attractive/BSP row
parallelism.  Z and the KL terms are psum'd.

Two KNN rings live here:

* :func:`ring_knn` — the *exact* oracle: each shard keeps its query slice
  and streams database shards around the ring, merging running top-k per
  hop — the transfer of hop t+1 overlaps the distance matmul of hop t.
  O(N²/S · D) compute per shard; the recall reference.
* :func:`ring_knn_approx` — the scalable path: every shard builds an
  rp-tree forest over its *local* points only, and the ring streams the
  (query block, running global top-k) state instead of database shards.
  At each hop the hosting shard routes the visiting queries down its own
  resident forest, scores just the ``n_trees * leaf_size`` leaf candidates
  exactly, and folds them into the traveling top-k with *global* indices.
  Per-hop compute is O(n_loc · T·leaf · D) — the N²/S distance tile is
  gone — and every merge is row-blocked (``block_rows``), so peak memory
  is bounded by the block size, not the shard size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import attractive, morton, quadtree
from repro.core._pairwise import pairwise_sq_dists
from repro.core.repulsive import bh_repulsion_sorted
from repro.core.summarize import summarize
from repro.core.tsne import GradResult


def _local_bh_gradient(y_loc, p_cols, p_vals, p_logp, *, axis, theta, exaggeration, depth):
    """shard_map body: y_loc [n_loc, 2]; P rows for the local points."""
    n_loc = y_loc.shape[0]
    rank = jax.lax.axis_index(axis)
    y_full = jax.lax.all_gather(y_loc, axis, tiled=True)          # [N, 2]
    n = y_full.shape[0]

    # replicated tree build (steps 3-4)
    cent, r_span = morton.span_radius(y_full)
    codes = morton.morton_encode(y_full, cent, r_span, depth=depth)
    codes_s, y_s, perm = quadtree.sort_points_by_code(y_full, codes)
    tree = quadtree.build_quadtree(codes_s, depth=depth)
    summ = summarize(tree, y_s, r_span)

    # local slice of sorted positions (inverse permutation of our indices)
    inv = jnp.zeros((n,), jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))
    my_pos = inv[rank * n_loc + jnp.arange(n_loc, dtype=jnp.int32)]

    # repulsion for local points only (step 6)
    theta2 = jnp.asarray(theta, y_loc.dtype) ** 2
    n_nodes = tree.n_nodes
    cap = tree.capacity
    is_leaf = tree.is_leaf

    def traverse(p, yp):
        def cond(state):
            return state[0] < n_nodes

        def body(state):
            ptr, force, z = state
            kk = jnp.minimum(ptr, cap - 1)
            s, e = tree.start[kk], tree.end[kk]
            cnt = summ.count[kk]
            inside = (s <= p) & (p < e)
            cnt_eff = cnt - jnp.where(inside, 1.0, 0.0)
            sum_eff = summ.sum_y[kk] - jnp.where(inside, yp, jnp.zeros_like(yp))
            com = sum_eff / jnp.maximum(cnt_eff, 1.0)
            diff = yp - com
            d2 = jnp.sum(diff * diff)
            side = summ.side[kk]
            open_ = (~is_leaf[kk]) & (side * side >= theta2 * d2)
            w = jnp.where(open_, 0.0, cnt_eff)
            q = 1.0 / (1.0 + d2)
            return (jnp.where(open_, ptr + 1, tree.skip[kk]),
                    force + (w * q * q) * diff, z + w * q)

        init = (jnp.int32(0), jnp.zeros((2,), y_loc.dtype), jnp.asarray(0.0, y_loc.dtype))
        _, force, z = jax.lax.while_loop(cond, body, init)
        return force, z

    f_rep, z_loc = jax.vmap(traverse)(my_pos, y_loc)
    z = jnp.maximum(jax.lax.psum(jnp.sum(z_loc), axis), 1e-30)

    # attractive for local rows (step 5) — cols are global indices
    yj = y_full[p_cols]
    diff = y_loc[:, None, :] - yj
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = p_vals / (1.0 + d2)
    f_attr = jnp.sum(pq[..., None] * diff, axis=1)
    kl_attr = jax.lax.psum(jnp.sum(p_vals * jnp.log1p(d2)), axis)

    grad = 4.0 * (jnp.asarray(exaggeration, y_loc.dtype) * f_attr - f_rep / z)
    kl = p_logp + kl_attr + jnp.log(z)
    return grad, kl, z


def distributed_bh_gradient(mesh, y, p_cols, p_vals, p_logp, *,
                            theta: float, exaggeration: float, depth: int = 16,
                            axis: str = "data") -> GradResult:
    """y [N,2] / p_cols, p_vals [N,K] sharded over ``axis`` (row-wise)."""
    fn = functools.partial(_local_bh_gradient, axis=axis, theta=theta,
                           exaggeration=exaggeration, depth=depth)
    grad, kl, z = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=(P(axis), P(), P()),
        check_vma=False,
    )(y, p_cols, p_vals, p_logp)
    return GradResult(grad=grad, kl=kl, z=z, max_traversal=jnp.int32(0))


# ---------------------------------------------------------------------------
# ring KNN
# ---------------------------------------------------------------------------

def ring_knn(mesh, x, k: int, axis: str = "data", *, n_valid: int | None = None):
    """Exact distributed KNN: x [N, D] sharded row-wise over ``axis``.

    Returns (idx [N,k] int32 global indices, d2 [N,k]), sharded like x.
    Each hop overlaps the next shard transfer (collective_permute) with the
    current distance tile (MXU matmul + top-k merge).  Rows >= ``n_valid``
    (default: all rows are valid) are padding — never emitted as neighbors.
    """
    n_dev = mesh.shape[axis]
    n_total = x.shape[0] if n_valid is None else int(n_valid)

    def body(xq):
        n_loc = xq.shape[0]
        rank = jax.lax.axis_index(axis)
        big = jnp.asarray(jnp.finfo(xq.dtype).max, xq.dtype)
        q_idx = rank * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def hop(carry, t):
            chunk, owner, best_d, best_i = carry
            # kick off the next transfer, then compute on the current chunk
            nxt = jax.lax.ppermute(chunk, axis, perm)
            nxt_owner = (owner - 1) % n_dev
            d2 = pairwise_sq_dists(xq, chunk)
            col = owner * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
            mask = (col[None, :] == q_idx[:, None]) | (col[None, :] >= n_total)
            d2 = jnp.where(mask, big, d2)
            cat_d = jnp.concatenate([best_d, d2], axis=1)
            cat_i = jnp.concatenate(
                [best_i, jnp.broadcast_to(col[None, :], d2.shape)], axis=1)
            neg, arg = jax.lax.top_k(-cat_d, k)
            return (nxt, nxt_owner, -neg, jnp.take_along_axis(cat_i, arg, axis=1)), None

        init = (xq, rank, jnp.full((n_loc, k), big, xq.dtype),
                jnp.full((n_loc, k), -1, jnp.int32))
        (chunk, _, best_d, best_i), _ = jax.lax.scan(hop, init, jnp.arange(n_dev))
        return best_i, jnp.maximum(best_d, 0.0)

    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=(P(axis), P(axis)), check_vma=False)(x)


# ---------------------------------------------------------------------------
# approximate candidate ring (sharded rp_forest)
# ---------------------------------------------------------------------------

def ring_knn_approx(
    mesh, x, k: int, axis: str = "data", *,
    n_valid: int | None = None,
    n_trees: int = 8,
    leaf_size: int = 64,
    block_rows: int = 4096,
    seed: int = 0,
):
    """Sharded approximate KNN: per-shard rp_forest + candidate ring.

    x [N, D] sharded row-wise over ``axis`` (N divisible by the axis size;
    rows >= ``n_valid`` are padding — they are scored as queries but their
    global indices are never emitted as neighbors).  Returns
    ``(idx [N, k] int32 global indices, d2 [N, k])``, sharded like x.

    Memory model: resident per shard is the local forest
    (``n_trees * [2^depth, leaf]`` int32 + thresholds) and the traveling
    state ``[n_loc, D + 2k]``; every hop's routing/scoring/merge runs over
    ``block_rows``-row slices (lax.map), so transients are
    O(block_rows * (n_trees*leaf_size + k)) regardless of N or shard size.
    Each query visits all S shards once (S hops) and comes home with the
    merged global top-k; a per-hop seed block (the host shard's first k+1
    points) guarantees k distinct valid indices even if forest candidates
    collapse to duplicates.
    """
    import math as _math

    from repro.neighbors.rp_forest import build_forest_index, route_to_leaves
    from repro.neighbors._candidates import candidate_sq_dists, merge_topk

    n_dev = mesh.shape[axis]
    n_pad_total, _ = x.shape
    if n_pad_total % n_dev:
        raise ValueError(f"N={n_pad_total} not divisible by {n_dev} shards")
    n_total = n_pad_total if n_valid is None else int(n_valid)
    n_loc = n_pad_total // n_dev
    if n_loc < k + 1:
        raise ValueError(
            f"shard size {n_loc} must exceed k={k}: lower the shard count"
        )
    # deepest split keeping leaves >= max(leaf_size, k+1) local points, the
    # same heuristic as RPForestNeighbors.resolve_depth
    leaf_floor = max(leaf_size, k + 1)
    depth = max(0, int(_math.floor(_math.log2(max(1.0, n_loc / leaf_floor)))))
    leaf = -(-n_loc // (1 << depth))
    n_pad_loc = leaf << depth
    n_seed = min(k + 1, n_loc)
    block = min(block_rows, n_loc)
    m_pad = -(-n_loc // block) * block
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def body(x_loc):
        rank = jax.lax.axis_index(axis)
        big = jnp.asarray(jnp.finfo(x_loc.dtype).max, x_loc.dtype)
        # decorrelate the per-shard forests: each rank draws its own
        # hyperplanes (fold by a prime so shard 1's seed never collides
        # with shard 0's tree-index folds)
        leaves, dirs, thrs = build_forest_index(
            x_loc, n_trees, depth, n_pad_loc, seed=seed + rank * 7919
        )
        base = rank * n_loc                     # global id of local row 0
        seed_cols = jnp.arange(n_seed, dtype=jnp.int32)[None, :]

        def merge_block(args):
            qb, gb, bi, bd = args
            cand = route_to_leaves(leaves, dirs, thrs, qb)     # local ids
            cand = jnp.concatenate(
                [cand, jnp.broadcast_to(seed_cols, (qb.shape[0], n_seed))],
                axis=1,
            )
            cd = candidate_sq_dists(x_loc, cand, block_rows=block, q=qb)
            # leaf pads (>= n_loc) and global pads (>= n_total) must never
            # escape as neighbor ids; -1 is dropped by merge_topk
            cand_g = jnp.where(cand < n_loc, base + cand, -1)
            cand_g = jnp.where(cand_g < n_total, cand_g, -1)
            cd = jnp.where(cand_g == gb[:, None], big, cd)     # self edge
            return merge_topk(bi, bd, cand_g, cd, k, n_total,
                              exclude_self=False)

        def hop(carry, _):
            q, gid, bi, bd = carry
            nb = m_pad // block
            blk = lambda a: a.reshape(nb, block, *a.shape[1:])
            mi, md = jax.lax.map(
                merge_block, (blk(q), blk(gid), blk(bi), blk(bd))
            )
            bi = mi.reshape(m_pad, k)
            bd = md.reshape(m_pad, k)
            # merged state travels on to the next shard's forest
            out = tuple(jax.lax.ppermute(a, axis, perm)
                        for a in (q, gid, bi, bd))
            return out, None

        gid = base + jnp.arange(n_loc, dtype=jnp.int32)
        pad = m_pad - n_loc
        q0 = jnp.pad(x_loc, ((0, pad), (0, 0)))
        gid0 = jnp.pad(gid, (0, pad), constant_values=-1)
        init = (
            q0, gid0,
            jnp.full((m_pad, k), -1, jnp.int32),
            jnp.full((m_pad, k), big, x_loc.dtype),
        )
        (q, gid, bi, bd), _ = jax.lax.scan(hop, init, None, length=n_dev)
        return bi[:n_loc], jnp.maximum(bd[:n_loc], 0.0)

    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=(P(axis), P(axis)), check_vma=False)(x)
