"""Core Barnes-Hut t-SNE library (the paper's contribution, in JAX)."""
from repro.core.morton import morton_encode, span_radius, DEFAULT_DEPTH
from repro.core.quadtree import build_quadtree, sort_points_by_code, LinearQuadtree
from repro.core.summarize import summarize, TreeSummary
from repro.core.repulsive import bh_repulsion_sorted, RepulsionResult
from repro.core.attractive import attractive_forces_ell, attractive_forces_edges
from repro.core.bsp import binary_search_perplexity, perplexity_of
from repro.core.knn import knn
from repro.core.tsne import (
    DEFAULT_ATTRACTIVE_IMPL, GradResult, IterationStats, NeighborGraph,
    TsneConfig, TsneResult, bh_gradient, init_state, preprocess, run_tsne,
    tsne_step,
)

__all__ = [
    "morton_encode", "span_radius", "DEFAULT_DEPTH",
    "build_quadtree", "sort_points_by_code", "LinearQuadtree",
    "summarize", "TreeSummary",
    "bh_repulsion_sorted", "RepulsionResult",
    "attractive_forces_ell", "attractive_forces_edges",
    "binary_search_perplexity", "perplexity_of",
    "knn",
    "DEFAULT_ATTRACTIVE_IMPL", "GradResult", "IterationStats", "NeighborGraph",
    "TsneConfig", "TsneResult", "run_tsne", "bh_gradient", "tsne_step",
    "preprocess", "init_state",
]
