"""Summarization (paper §3.4) — per-cell center-of-mass, TPU formulation.

daal4py runs a *sequential* bottom-up pass; the paper parallelizes it level by
level.  With Morton-sorted points every node is a contiguous range, so the
center-of-mass of *every* node at *every* level is an O(1) difference of
coordinate prefix sums — strictly more parallel than level-synchronous
reduction: one cumsum + one gather, no level barriers at all.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quadtree import LinearQuadtree


class TreeSummary(NamedTuple):
    count: jax.Array      # [cap] float, points per node
    sum_y: jax.Array      # [cap, 2] coordinate sums per node
    com: jax.Array        # [cap, 2] centers of mass (safe for empty nodes)
    side: jax.Array       # [cap] cell side length (2*r_span / 2^level)


def summarize(tree: LinearQuadtree, y_sorted: jax.Array, r_span: jax.Array) -> TreeSummary:
    n = y_sorted.shape[0]
    # center before the prefix sum: the cumsum error is O(sqrt(N) * eps * |y|),
    # so removing the mean keeps float32 COMs accurate even at N ~ 1e6
    mu = jnp.mean(y_sorted, axis=0, keepdims=True)
    yc = y_sorted - mu
    csum = jnp.concatenate(
        [jnp.zeros((1, y_sorted.shape[1]), y_sorted.dtype), jnp.cumsum(yc, axis=0)],
        axis=0,
    )  # [N+1, 2]
    start = jnp.clip(tree.start, 0, n)
    end = jnp.clip(tree.end, 0, n)
    sum_yc = csum[end] - csum[start]
    count = (end - start).astype(y_sorted.dtype)
    com = mu + sum_yc / jnp.maximum(count, 1.0)[:, None]
    sum_y = sum_yc + count[:, None] * mu
    side = (2.0 * r_span) * jnp.exp2(-tree.level.astype(y_sorted.dtype))
    return TreeSummary(count=count, sum_y=sum_y, com=com, side=side.astype(y_sorted.dtype))
