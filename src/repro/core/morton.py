"""Morton (Z-order) code formation — paper Algorithm 1, adapted to TPU.

The paper forms 64-bit Morton codes (32 bits/dim) with AVX-512 auto-vectorized
bit interleaving.  On TPU (and to stay independent of jax x64 mode) we default
to 32-bit codes (16 bits/dim, quadtree depth 16).  At float32 embedding
precision, 2^-16 relative cell resolution is far below optimization noise; the
paper's own choice of 64-bit was driven by double precision.

All functions are jit-safe and shape-polymorphic over the leading point axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

DEFAULT_DEPTH = 16  # quadtree levels below the root; 2 bits/level -> 32-bit code


def auto_depth(n: int) -> int:
    """Depth that keeps ~<1 expected point per finest cell with margin.

    The paper fixes 32 levels (64-bit codes); levels beyond ~log4(N)+2 are
    pure overhead (every added level costs an O(N) pass in build/summarize),
    so the adaptive policy is a measured §Perf improvement on the build step.
    """
    import math

    return int(min(16, max(8, math.ceil(math.log2(max(n, 2)) / 2) + 4)))

# Magic masks for 16 -> 32 bit interleave (paper Alg. 1 lines 9-18, 32-bit form).
_MASKS_U32 = (
    (8, 0x00FF00FF),
    (4, 0x0F0F0F0F),
    (2, 0x33333333),
    (1, 0x55555555),
)


def expand_bits_u32(v: jax.Array) -> jax.Array:
    """Spread the low 16 bits of ``v`` so bit i moves to bit 2i (uint32)."""
    v = v.astype(jnp.uint32) & jnp.uint32(0x0000FFFF)
    for shift, mask in _MASKS_U32:
        v = (v | (v << shift)) & jnp.uint32(mask)
    return v


def span_radius(y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Bounding-square center and half-span (r_span) of embedding ``y [N,2]``.

    Mirrors the paper: the root cell is the square centered at ``cent`` with
    half side ``r_span`` covering min/max along both dims.
    """
    lo = jnp.min(y, axis=0)
    hi = jnp.max(y, axis=0)
    cent = 0.5 * (lo + hi)
    # strictly positive span so the scale below is finite for degenerate inputs
    r = jnp.maximum(jnp.max(0.5 * (hi - lo)), jnp.asarray(1e-30, y.dtype))
    # tiny inflation so points on the max boundary land inside the last cell
    r = r * (1.0 + 1e-6) + 1e-30
    return cent, r


@functools.partial(jax.jit, static_argnames=("depth",))
def morton_encode(
    y: jax.Array,
    cent: jax.Array,
    r_span: jax.Array,
    depth: int = DEFAULT_DEPTH,
) -> jax.Array:
    """Paper Algorithm 1: embedding points -> Morton codes (uint32).

    y      : [N, 2] float embedding points
    cent   : [2] center of the root cell
    r_span : scalar half-span of the root cell
    depth  : bits per dimension (<= 16 for uint32 codes)
    """
    if not 1 <= depth <= 16:
        raise ValueError(f"depth must be in [1, 16] for uint32 codes, got {depth}")
    y_root = cent - r_span                      # Alg.1 line 4
    scale = (2.0 ** (depth - 1)) / r_span       # Alg.1 line 5 (2^31/r -> 2^(d-1)/r)
    m = (y - y_root[None, :]) * scale.astype(y.dtype)
    m = jnp.clip(m, 0.0, float(2**depth) - 1.0).astype(jnp.uint32)
    mx = expand_bits_u32(m[..., 0])
    my = expand_bits_u32(m[..., 1])
    code = mx | (my << 1)                       # Alg.1 line 21
    if depth < 16:
        # keep codes left-aligned at bit 2*depth so prefix logic is uniform
        code = code & jnp.uint32((1 << (2 * depth)) - 1)
    return code


def morton_decode_cell(code: jax.Array, level: int, depth: int = DEFAULT_DEPTH):
    """Integer (x, y) cell coordinates of ``code``'s prefix at ``level``."""
    pfx = code >> jnp.uint32(2 * (depth - level))
    x = _compact_bits_u32(pfx)
    y = _compact_bits_u32(pfx >> 1)
    return x, y


def _compact_bits_u32(v: jax.Array) -> jax.Array:
    v = v.astype(jnp.uint32) & jnp.uint32(0x55555555)
    v = (v | (v >> 1)) & jnp.uint32(0x33333333)
    v = (v | (v >> 2)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v >> 4)) & jnp.uint32(0x00FF00FF)
    v = (v | (v >> 8)) & jnp.uint32(0x0000FFFF)
    return v
