"""Jittable linear quadtree built from sorted Morton codes.

This is the TPU-native reformulation of the paper's §3.3 "Parallel Quadtree
Building".  The CPU version builds pointer-based nodes with subtree-parallel
threads; here the *entire* build is a fixed-shape data-parallel pipeline:

  1. sort Morton codes (one O(N log N) sort, each point touched once — the
     paper's headline improvement over daal4py's per-level re-partitioning);
  2. for every level L, run boundaries of the depth-L prefix mark candidate
     cells; a candidate is a *node* iff its point range differs from the run
     one level deeper (keeps the deepest cell of every single-child chain —
     the compressed quadtree, <= 2N-1 nodes);
  3. nodes are emitted directly in DFS pre-order — flattening the (point,
     level) keep-grid point-major/level-minor *is* (start asc, depth asc) =
     pre-order for a laminar range family — no extra sort needed;
  4. ``skip`` rope pointers (next node in DFS skipping the subtree) come from
     one vectorized ``searchsorted`` over the node starts.

The traversal then never chases pointers: ``ptr = open ? ptr+1 : skip[ptr]``.

Node ranges index into the Morton-sorted point order.  Summaries (count,
center-of-mass) are O(1) per node via prefix sums of the sorted coordinates —
see summarize.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.morton import DEFAULT_DEPTH


class LinearQuadtree(NamedTuple):
    """Fixed-capacity (2N+1 slots) compressed quadtree in DFS pre-order.

    Valid nodes occupy slots [0, n_nodes); the remainder are inert padding
    with ``start == end == N`` so every vectorized op over slots is harmless.
    """

    start: jax.Array    # [cap] int32, point-range start (sorted order)
    end: jax.Array      # [cap] int32, point-range end (exclusive)
    level: jax.Array    # [cap] int32, tree depth of the cell (root region = 0)
    skip: jax.Array     # [cap] int32, DFS skip pointer (>= n_nodes terminates)
    n_nodes: jax.Array  # [] int32
    depth: int          # static max depth

    @property
    def count(self) -> jax.Array:
        return self.end - self.start

    @property
    def is_leaf(self) -> jax.Array:
        return self.skip == jnp.arange(self.skip.shape[0], dtype=jnp.int32) + 1

    @property
    def capacity(self) -> int:
        return self.start.shape[0]


def _run_ends(boundary: jax.Array, n: int) -> jax.Array:
    """end[i] = index of the next run boundary strictly after i (else n)."""
    idx = jnp.arange(n, dtype=jnp.int32)
    t = jnp.where(boundary, idx, jnp.int32(n))
    # suffix minimum: sm[i] = min(t[i:])
    sm = jax.lax.cummin(t, axis=0, reverse=True)
    return jnp.concatenate([sm[1:], jnp.full((1,), n, jnp.int32)])


@functools.partial(jax.jit, static_argnames=("depth", "compress"))
def build_quadtree(
    sorted_codes: jax.Array, depth: int = DEFAULT_DEPTH, compress: bool = True
) -> LinearQuadtree:
    """Build the compressed linear quadtree from *sorted* Morton codes.

    compress=False keeps every per-level run as a node (single-child chains
    included) — the daal4py-like uncompressed tree used as the benchmark
    baseline; capacity grows to (depth+1)*N.
    """
    n = sorted_codes.shape[0]
    cap = (2 * n + 1) if compress else ((depth + 1) * n + 1)
    ends = []
    bounds = []
    for lvl in range(depth + 1):
        if lvl == 0:
            boundary = jnp.zeros((n,), bool).at[0].set(True)
        else:
            pfx = sorted_codes >> jnp.uint32(2 * (depth - lvl))
            prev = jnp.concatenate([pfx[:1] ^ jnp.uint32(1), pfx[:-1]])
            boundary = pfx != prev
            boundary = boundary.at[0].set(True)
        bounds.append(boundary)
        ends.append(_run_ends(boundary, n))

    # node keep rule: boundary AND (max depth OR splits at the next level)
    keeps = []
    for lvl in range(depth + 1):
        if lvl == depth or not compress:
            keeps.append(bounds[lvl])
        else:
            keeps.append(bounds[lvl] & (ends[lvl + 1] < ends[lvl]))

    # [N, depth+1] grids flattened point-major => DFS pre-order
    keep = jnp.stack(keeps, axis=1).reshape(-1)
    end_flat = jnp.stack(ends, axis=1).reshape(-1)
    idx = jnp.arange(n, dtype=jnp.int32)
    start_flat = jnp.broadcast_to(idx[:, None], (n, depth + 1)).reshape(-1)
    lvl_flat = jnp.broadcast_to(
        jnp.arange(depth + 1, dtype=jnp.int32)[None, :], (n, depth + 1)
    ).reshape(-1)

    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    n_nodes = rank[-1] + 1
    pos = jnp.where(keep, rank, cap)  # cap = trash slot of a (cap+1) array

    def scatter(values, fill):
        out = jnp.full((cap + 1,), fill, jnp.int32)
        out = out.at[pos].set(values.astype(jnp.int32), mode="drop")
        return out[:cap]

    start = scatter(start_flat, n)
    end = scatter(end_flat, n)
    level = scatter(lvl_flat, 0)

    # DFS skip pointer: first node whose range starts at/after our end.
    skip = jnp.searchsorted(start, end, side="left").astype(jnp.int32)
    return LinearQuadtree(start=start, end=end, level=level, skip=skip,
                          n_nodes=n_nodes.astype(jnp.int32), depth=depth)


def sort_points_by_code(y: jax.Array, codes: jax.Array):
    """Sort points by Morton code; returns (codes_sorted, y_sorted, perm)."""
    perm = jnp.argsort(codes)
    return codes[perm], y[perm], perm
