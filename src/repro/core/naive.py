"""daal4py-like *naive* BH t-SNE steps — the paper's baseline, reimplemented.

The paper's baseline (daal4py v2021.6) builds the quadtree level by level,
re-partitioning every point at every level, runs a *sequential* bottom-up
summarization with level barriers, and a scalar-inner-loop attractive pass.
These emulations preserve that work profile (per-level point passes, per-level
sorts, level-synchronized reductions, sequential inner loop) so benchmark
ratios measure the paper's algorithmic win rather than implementation noise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import morton


@functools.partial(jax.jit, static_argnames=("depth",))
def naive_build_and_summarize(y: jax.Array, depth: int = 16):
    """Level-by-level build: every level re-buckets and re-sorts all points
    (daal4py 'each point traversed as many times as the depth'), then runs a
    level-synchronized summarization pass per level."""
    n = y.shape[0]
    cent, r_span = morton.span_radius(y)
    cx = jnp.full((n,), cent[0], y.dtype)
    cy = jnp.full((n,), cent[1], y.dtype)
    half = r_span
    ids = jnp.zeros((n,), jnp.uint32)
    coms = []
    counts = []
    for _ in range(depth):
        qx = (y[:, 0] > cx).astype(jnp.uint32)
        qy = (y[:, 1] > cy).astype(jnp.uint32)
        ids = ids * 4 + (qx + 2 * qy)
        half = half * 0.5
        cx = cx + (2.0 * qx.astype(y.dtype) - 1.0) * half
        cy = cy + (2.0 * qy.astype(y.dtype) - 1.0) * half
        # per-level re-partition: sort all points by this level's cell id
        order = jnp.argsort(ids)
        ids_s = ids[order]
        y_s = y[order]
        # level-synchronized summarization (one barrier per level)
        seg_new = jnp.concatenate([jnp.ones((1,), bool), ids_s[1:] != ids_s[:-1]])
        seg = jnp.cumsum(seg_new.astype(jnp.int32)) - 1
        csum = jax.ops.segment_sum(y_s, seg, num_segments=n)
        ccnt = jax.ops.segment_sum(jnp.ones((n,), y.dtype), seg, num_segments=n)
        coms.append(csum / jnp.maximum(ccnt, 1.0)[:, None])
        counts.append(ccnt)
    return ids, coms, counts


@jax.jit
def naive_attractive(y: jax.Array, cols: jax.Array, vals: jax.Array):
    """Algorithm 2 with a *sequential* inner loop over neighbors (the
    pre-SIMD baseline): vmap over rows, fori_loop over K."""
    k = cols.shape[1]

    def row(yi, ci, vi):
        def body(j, acc):
            yj = y[ci[j]]
            diff = yi - yj
            d2 = diff @ diff
            pq = vi[j] / (1.0 + d2)
            return acc + pq * diff

        return jax.lax.fori_loop(0, k, body, jnp.zeros_like(yi))

    return jax.vmap(row)(y, cols, vals)
