"""Repulsive force via Barnes-Hut traversal (paper §3.5), TPU formulation.

The CPU implementation does a recursive DFS per point, relying on the
Morton-ordered node layout for cache locality.  The TPU equivalent is a
*rope-linearized* traversal: nodes live in DFS pre-order arrays and each point
walks ``ptr = open ? ptr+1 : skip[ptr]`` inside a ``lax.while_loop``.  vmapping
the loop over points gives lockstep masked execution — the accelerator
analogue of the paper's "structured data locality" DFS (all lanes read from
the same contiguous node arrays, near the front of the array most of the
time, which is exactly the locality argument of §3.5 restated for VMEM/HBM).

Self-interaction is excluded *exactly*: when the current node's point range
contains the query point (known from its position in Morton-sorted order) the
summary is used with the query point subtracted.

Opening criterion (paper eq. 9, van-der-Maaten form): use the summary iff
``side_cell / dist < theta`` — i.e. *open* iff ``side^2 >= theta^2 * d^2``.
Leaves (terminal runs: singletons or max-depth duplicate-code runs) always
contribute their (self-excluded) summary; the Student-t kernel is smooth at
d = 0 so coincident points need no special casing.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quadtree import LinearQuadtree
from repro.core.summarize import TreeSummary


class RepulsionResult(NamedTuple):
    force: jax.Array       # [N, 2] unnormalized: sum_j (1+d^2)^-2 (y_i - y_j)
    z_per_point: jax.Array  # [N] sum_j (1+d^2)^-1
    steps: jax.Array       # [N] traversal lengths (perf diagnostic)


@functools.partial(jax.jit, static_argnames=())
def bh_repulsion_sorted(
    y_sorted: jax.Array,
    tree: LinearQuadtree,
    summary: TreeSummary,
    theta: jax.Array | float,
) -> RepulsionResult:
    """Barnes-Hut repulsion for points in Morton-sorted order."""
    n = y_sorted.shape[0]
    dtype = y_sorted.dtype
    theta2 = jnp.asarray(theta, dtype) ** 2
    n_nodes = tree.n_nodes
    cap = tree.capacity
    is_leaf = tree.is_leaf

    def traverse(p, yp):
        def cond(state):
            ptr, _, _, _ = state
            return ptr < n_nodes

        def body(state):
            ptr, force, z, steps = state
            k = jnp.minimum(ptr, cap - 1)
            s = tree.start[k]
            e = tree.end[k]
            cnt = summary.count[k]
            inside = (s <= p) & (p < e)
            cnt_eff = cnt - jnp.where(inside, jnp.asarray(1.0, dtype), 0.0)
            sum_eff = summary.sum_y[k] - jnp.where(inside, yp, jnp.zeros_like(yp))
            com = sum_eff / jnp.maximum(cnt_eff, 1.0)
            diff = yp - com
            d2 = jnp.sum(diff * diff)
            side = summary.side[k]
            open_ = (~is_leaf[k]) & (side * side >= theta2 * d2)
            w = jnp.where(open_, 0.0, cnt_eff)          # contribute iff accepted
            q = 1.0 / (1.0 + d2)
            z = z + w * q
            force = force + (w * q * q) * diff
            ptr = jnp.where(open_, ptr + 1, tree.skip[k])
            return ptr, force, z, steps + 1

        init = (jnp.int32(0), jnp.zeros((2,), dtype), jnp.asarray(0.0, dtype), jnp.int32(0))
        _, force, z, steps = jax.lax.while_loop(cond, body, init)
        return force, z, steps

    force, z, steps = jax.vmap(traverse)(jnp.arange(n, dtype=jnp.int32), y_sorted)
    return RepulsionResult(force=force, z_per_point=z, steps=steps)


def bh_repulsion(y: jax.Array, codes: jax.Array, tree_builder, theta):
    """Convenience wrapper operating in original point order (see tsne.py)."""
    raise NotImplementedError("use repro.core.tsne.gradient_step")
