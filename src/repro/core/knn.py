"""Exact K-nearest-neighbors (paper §3.1).

The paper reuses daal4py's KNN; we must build the substrate ourselves.  The
TPU-native formulation is a *blocked brute force*: the query x database
squared-distance tile is an MXU matmul (`-2 q @ x^T`) plus rank-1 norm
epilogue (the Pallas kernel in kernels/pairwise_kernel.py), and the top-K is
a streaming `lax.top_k` merge over database chunks, so the working set stays
in VMEM-sized tiles.  Exact (not approximate) — matches the paper's accuracy
claims.  Distributed ring variant lives in core/distributed.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _pad_to(x: jax.Array, multiple: int, axis: int = 0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_db", "pairwise_fn_name")
)
def knn(
    x: jax.Array,
    k: int,
    block_q: int = 512,
    block_db: int = 2048,
    pairwise_fn_name: str = "xla",
):
    """Exact KNN. Returns (idx [N,k] int32, d2 [N,k]) — self excluded.

    pairwise_fn_name: "xla" (jnp) or "pallas" (kernels.pairwise_kernel).
    """
    n, _ = x.shape
    if k >= n:
        raise ValueError(f"k={k} must be < n={n}")
    if pairwise_fn_name == "pallas":
        from repro.kernels.ops import pairwise_sq_dists as pw
    else:
        from repro.core._pairwise import pairwise_sq_dists as pw

    xp, _ = _pad_to(x, block_db, axis=0)
    n_pad = xp.shape[0]
    sqn = jnp.sum(xp * xp, axis=1)
    n_chunks = n_pad // block_db

    qs_pad, _ = _pad_to(x, block_q, axis=0)
    q_sqn = jnp.sum(qs_pad * qs_pad, axis=1)
    n_qblocks = qs_pad.shape[0] // block_q
    big = jnp.asarray(jnp.finfo(x.dtype).max, x.dtype)

    def one_qblock(qb):
        q = jax.lax.dynamic_slice_in_dim(qs_pad, qb * block_q, block_q)
        qn = jax.lax.dynamic_slice_in_dim(q_sqn, qb * block_q, block_q)
        q_idx = qb * block_q + jnp.arange(block_q, dtype=jnp.int32)

        def scan_chunk(carry, c):
            best_d, best_i = carry
            db = jax.lax.dynamic_slice_in_dim(xp, c * block_db, block_db)
            dbn = jax.lax.dynamic_slice_in_dim(sqn, c * block_db, block_db)
            col = c * block_db + jnp.arange(block_db, dtype=jnp.int32)
            d2 = pw(q, db, qn, dbn)                       # [block_q, block_db]
            invalid = (col[None, :] >= n) | (col[None, :] == q_idx[:, None])
            d2 = jnp.where(invalid, big, d2)
            cat_d = jnp.concatenate([best_d, d2], axis=1)
            cat_i = jnp.concatenate(
                [best_i, jnp.broadcast_to(col[None, :], d2.shape)], axis=1
            )
            neg_top, argtop = jax.lax.top_k(-cat_d, k)
            return (-neg_top, jnp.take_along_axis(cat_i, argtop, axis=1)), None

        init = (jnp.full((block_q, k), big, x.dtype), jnp.full((block_q, k), -1, jnp.int32))
        (best_d, best_i), _ = jax.lax.scan(scan_chunk, init, jnp.arange(n_chunks))
        return best_d, best_i

    best_d, best_i = jax.lax.map(one_qblock, jnp.arange(n_qblocks))
    best_d = best_d.reshape(-1, k)[:n]
    best_i = best_i.reshape(-1, k)[:n]
    return best_i, jnp.maximum(best_d, 0.0)


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_db", "pairwise_fn_name")
)
def knn_query(
    q: jax.Array,
    db: jax.Array,
    k: int,
    block_q: int = 512,
    block_db: int = 2048,
    pairwise_fn_name: str = "xla",
):
    """Exact KNN of query points against a fixed database (out-of-sample).

    Unlike :func:`knn`, rows of ``q`` are *not* members of ``db``, so no
    diagonal exclusion applies — the true nearest database point is a valid
    answer.  Returns (idx [M,k] int32 into db, d2 [M,k]).
    """
    m = q.shape[0]
    n = db.shape[0]
    if k > n:
        raise ValueError(f"k={k} must be <= database size n={n}")
    if pairwise_fn_name == "pallas":
        from repro.kernels.ops import pairwise_sq_dists as pw
    else:
        from repro.core._pairwise import pairwise_sq_dists as pw

    dbp, _ = _pad_to(db, block_db, axis=0)
    n_pad = dbp.shape[0]
    sqn = jnp.sum(dbp * dbp, axis=1)
    n_chunks = n_pad // block_db

    qp, _ = _pad_to(q, block_q, axis=0)
    q_sqn = jnp.sum(qp * qp, axis=1)
    n_qblocks = qp.shape[0] // block_q
    big = jnp.asarray(jnp.finfo(q.dtype).max, q.dtype)

    def one_qblock(qb):
        qq = jax.lax.dynamic_slice_in_dim(qp, qb * block_q, block_q)
        qn = jax.lax.dynamic_slice_in_dim(q_sqn, qb * block_q, block_q)

        def scan_chunk(carry, c):
            best_d, best_i = carry
            chunk = jax.lax.dynamic_slice_in_dim(dbp, c * block_db, block_db)
            dbn = jax.lax.dynamic_slice_in_dim(sqn, c * block_db, block_db)
            col = c * block_db + jnp.arange(block_db, dtype=jnp.int32)
            d2 = pw(qq, chunk, qn, dbn)                   # [block_q, block_db]
            d2 = jnp.where(col[None, :] >= n, big, d2)
            cat_d = jnp.concatenate([best_d, d2], axis=1)
            cat_i = jnp.concatenate(
                [best_i, jnp.broadcast_to(col[None, :], d2.shape)], axis=1
            )
            neg_top, argtop = jax.lax.top_k(-cat_d, k)
            return (-neg_top, jnp.take_along_axis(cat_i, argtop, axis=1)), None

        init = (jnp.full((block_q, k), big, q.dtype),
                jnp.full((block_q, k), -1, jnp.int32))
        (best_d, best_i), _ = jax.lax.scan(scan_chunk, init, jnp.arange(n_chunks))
        return best_d, best_i

    best_d, best_i = jax.lax.map(one_qblock, jnp.arange(n_qblocks))
    return (best_i.reshape(-1, k)[:m],
            jnp.maximum(best_d.reshape(-1, k)[:m], 0.0))
