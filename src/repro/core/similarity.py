"""Sparse input-similarity construction (paper §2.2.1).

Produces the symmetric p_ij = (p_{j|i} + p_{i|j}) / 2N over the union of the
directed KNN neighborhoods in two interchangeable layouts:

* ``symmetrize_ell`` — host-side (numpy) construction of a regular ELL
  [N, W] matrix, W = max symmetric row degree (<= K + max indegree).  Runs
  once before gradient descent, so host preprocessing is fine; the GD loop
  then uses paper-Algorithm-2 verbatim (attractive_forces_ell).
* ``edge_list`` — jit-safe directed edge list of N*K edges; each edge is
  applied to both endpoints by attractive_forces_edges, so the symmetric
  sum over ordered pairs is recovered without materializing it.  Used by
  the fully jitted / distributed path; numerically identical forces.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def edge_list(cols, cond_p, n: int | None = None):
    """Directed KNN edges: (src [NK], dst [NK], w [NK] = p_{dst|src} / 2N)."""
    cols = jnp.asarray(cols)
    cond_p = jnp.asarray(cond_p)
    nn, k = cols.shape
    n = n or nn
    src = jnp.repeat(jnp.arange(nn, dtype=jnp.int32), k)
    dst = cols.reshape(-1).astype(jnp.int32)
    w = cond_p.reshape(-1) / (2.0 * n)
    return src, dst, w


def symmetrize_ell(cols, cond_p):
    """Host-side symmetrization to a regular ELL layout.

    cols   : [N, K] int neighbor indices
    cond_p : [N, K] conditional p_{j|i}
    Returns (sym_cols [N, W] int32, sym_vals [N, W] float) where padding
    entries have col = row-index and val = 0; sum(sym_vals) == 1.
    """
    cols = np.asarray(cols)
    cond_p = np.asarray(cond_p)
    n, k = cols.shape
    rows = np.repeat(np.arange(n, dtype=np.int64), k)
    cs = cols.reshape(-1).astype(np.int64)
    vs = cond_p.reshape(-1).astype(np.float64)
    # both orientations; duplicates (mutual neighbors) sum to p_{j|i}+p_{i|j}
    r2 = np.concatenate([rows, cs])
    c2 = np.concatenate([cs, rows])
    v2 = np.concatenate([vs, vs])
    key = r2 * n + c2
    order = np.argsort(key, kind="stable")
    key, r2, c2, v2 = key[order], r2[order], c2[order], v2[order]
    new_run = np.empty(key.shape, bool)
    new_run[0] = True
    new_run[1:] = key[1:] != key[:-1]
    run_id = np.cumsum(new_run) - 1
    n_runs = run_id[-1] + 1
    val = np.zeros(n_runs, np.float64)
    np.add.at(val, run_id, v2)
    row = r2[new_run]
    col = c2[new_run]
    # rank within row
    row_start = np.zeros(n_runs, np.int64)
    first_of_row = np.empty(n_runs, bool)
    first_of_row[0] = True
    first_of_row[1:] = row[1:] != row[:-1]
    row_first_idx = np.maximum.accumulate(np.where(first_of_row, np.arange(n_runs), 0))
    rank = np.arange(n_runs) - row_first_idx
    w = int(rank.max()) + 1 if n_runs else 1
    sym_cols = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, w))
    sym_vals = np.zeros((n, w), np.float64)
    sym_cols[row, rank] = col.astype(np.int32)
    sym_vals[row, rank] = val / (2.0 * n)
    return sym_cols, sym_vals


def symmetrize_ell_chunked(cols, cond_p, chunk_size: int):
    """Streaming-CSR symmetrization: :func:`symmetrize_ell` in row chunks.

    Bit-identical output to ``symmetrize_ell`` (same [N, W] layout, same
    values — parity-tested), but the 2NK-edge concatenate-and-argsort of
    the reference never materializes.  Memory model:

    * one-shot transpose of the directed graph (incoming edges grouped by
      destination) via a stable integer sort of the NK column indices —
      O(N·K) arrays, the same order as the KNN output itself;
    * per chunk of rows, the reference's key-sort/dedup/rank merge runs
      over that chunk's outgoing + incoming edges only — O(chunk·K)
      transients;
    * the accumulated merged triples total the symmetric nnz (<= 2NK),
      i.e. output-order memory, filled into the ELL planes at the end
      once the global width W is known.

    Nothing here is ever O(N²) or holds more than O(chunk·K) beyond the
    O(N·K) inputs/outputs.
    """
    chunk = int(chunk_size)
    if chunk <= 0:
        raise ValueError(f"chunk_size={chunk_size} must be >= 1")
    cols = np.asarray(cols)
    cond_p = np.asarray(cond_p)
    n, k = cols.shape

    # transpose: incoming edges of row j live at t_order[t_ptr[j]:t_ptr[j+1]]
    flat_cols = cols.reshape(-1).astype(np.int64)
    indeg = np.bincount(flat_cols, minlength=n)
    t_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(indeg, out=t_ptr[1:])
    t_order = np.argsort(flat_cols, kind="stable")
    t_src = (t_order // k).astype(np.int64)          # source row per in-edge
    t_val = cond_p.reshape(-1).astype(np.float64)[t_order]

    parts = []          # (rows, ranks, cols, vals) per chunk — sym nnz total
    w = 1
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        r2 = np.concatenate([
            np.repeat(np.arange(s, e, dtype=np.int64), k),       # outgoing
            np.repeat(np.arange(s, e, dtype=np.int64),           # incoming
                      indeg[s:e]),
        ])
        c2 = np.concatenate([
            cols[s:e].reshape(-1).astype(np.int64),
            t_src[t_ptr[s]:t_ptr[e]],
        ])
        v2 = np.concatenate([
            cond_p[s:e].reshape(-1).astype(np.float64),
            t_val[t_ptr[s]:t_ptr[e]],
        ])
        key = (r2 - s) * n + c2
        order = np.argsort(key, kind="stable")
        key, r2, c2, v2 = key[order], r2[order], c2[order], v2[order]
        new_run = np.empty(key.shape, bool)
        new_run[0] = True
        new_run[1:] = key[1:] != key[:-1]
        run_id = np.cumsum(new_run) - 1
        n_runs = run_id[-1] + 1
        val = np.zeros(n_runs, np.float64)
        np.add.at(val, run_id, v2)
        row = r2[new_run]
        col = c2[new_run]
        first_of_row = np.empty(n_runs, bool)
        first_of_row[0] = True
        first_of_row[1:] = row[1:] != row[:-1]
        row_first_idx = np.maximum.accumulate(
            np.where(first_of_row, np.arange(n_runs), 0))
        rank = np.arange(n_runs) - row_first_idx
        w = max(w, int(rank.max()) + 1 if n_runs else 1)
        parts.append((row.astype(np.int64), rank.astype(np.int32),
                      col.astype(np.int32), val))

    sym_cols = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, w))
    sym_vals = np.zeros((n, w), np.float64)
    for row, rank, col, val in parts:
        sym_cols[row, rank] = col
        sym_vals[row, rank] = val / (2.0 * n)
    return sym_cols, sym_vals


def dense_p_matrix(cols, cond_p):
    """Dense symmetric P (for the exact oracle / small-N tests)."""
    cols = np.asarray(cols)
    cond_p = np.asarray(cond_p)
    n, k = cols.shape
    p = np.zeros((n, n), np.float64)
    rows = np.repeat(np.arange(n), k)
    p[rows, cols.reshape(-1)] = cond_p.reshape(-1)
    p = (p + p.T) / (2.0 * n)
    np.fill_diagonal(p, 0.0)
    return p
