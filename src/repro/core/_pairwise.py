"""Pure-XLA pairwise squared distances (the jnp counterpart of the Pallas
pairwise kernel; also its correctness oracle)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_dists(q, db, q_sqn=None, db_sqn=None):
    """||q_i - db_j||^2 as an MXU matmul + rank-1 epilogue. [Q, D] x [C, D] -> [Q, C]."""
    if q_sqn is None:
        q_sqn = jnp.sum(q * q, axis=1)
    if db_sqn is None:
        db_sqn = jnp.sum(db * db, axis=1)
    dots = q @ db.T
    return q_sqn[:, None] + db_sqn[None, :] - 2.0 * dots
