"""Binary Search Perplexity (paper §3.2): XLA reference + Pallas dispatch.

Prior CPU implementations were single-threaded; the paper multithreads the
per-point search with Numba prange.  Two interchangeable implementations
live behind :func:`binary_search_perplexity`'s ``impl=`` switch:

* ``"xla"`` — the branch-free vectorized formulation below: one
  ``fori_loop`` whose every bisection step passes over the whole [N, K]
  array ("as many threads as points").  Simple, but each of the ~64
  iterations re-reads d2 from memory.
* ``"pallas"`` — the fused tile kernel
  (``kernels/bsp_kernel.binary_search_perplexity_pallas``, registered as
  ``bsp_search`` in the ``kernels/ops`` registry): d2 is tiled over the point
  axis and the *entire* per-row bisection runs in one VMEM-resident grid
  step, so d2 is read once instead of ``iters`` times.  Interpret-mode on
  CPU, compiled on TPU — see docs/KERNELS.md for the dispatch convention
  and the roofline analysis that picked this target.

Both return identical (cond_p, beta) to float tolerance (parity-tested in
``tests/test_kernels.py``).  The search variable is beta_i = 1/(2 sigma_i^2),
matching scikit-learn's ``_binary_search_perplexity``; ``TsneConfig.bsp_impl``
selects the implementation for the fit pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

BSP_IMPLS = ("xla", "pallas")


def binary_search_perplexity(
    d2: jax.Array,
    perplexity: float,
    iters: int = 64,
    tol: float = 1e-5,
    impl: str = "xla",
):
    """Conditional similarities p_{j|i} with per-row perplexity == target.

    d2 : [N, K] squared distances to the K nearest neighbors (self excluded)
    impl : "xla" (vectorized whole-array loop) | "pallas" (fused tile kernel)
    Returns (cond_p [N, K], beta [N]).
    """
    if impl == "pallas":
        from repro.kernels.ops import binary_search_perplexity as pallas_bsp
        return pallas_bsp(d2, perplexity, iters=iters, tol=tol)
    if impl != "xla":
        raise ValueError(
            f"unknown bsp impl {impl!r} (known: {', '.join(BSP_IMPLS)})"
        )
    return _binary_search_perplexity_xla(d2, perplexity, iters, tol)


@functools.partial(jax.jit, static_argnames=("iters",))
def _binary_search_perplexity_xla(
    d2: jax.Array,
    perplexity: float,
    iters: int = 64,
    tol: float = 1e-5,
):
    dtype = d2.dtype
    n = d2.shape[0]
    log_u = jnp.asarray(jnp.log(perplexity), dtype)
    inf = jnp.asarray(jnp.inf, dtype)

    # conditioning guards (the paper computes in float64; float32 needs both):
    # 1. shift by the row min — p_{j|i} is shift-invariant and exp(0)=1 keeps
    #    the nearest neighbor from underflowing at large beta;
    # 2. scale by the row mean so beta ~ O(1) across datasets.
    d2s = d2 - jnp.min(d2, axis=1, keepdims=True)
    scale = jnp.maximum(jnp.mean(d2s, axis=1, keepdims=True), jnp.asarray(1e-30, dtype))
    d2n = d2s / scale

    def entropy(beta):
        # beta: [N,1]
        p = jnp.exp(-d2n * beta)
        sum_p = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
        h = jnp.log(sum_p) + beta * jnp.sum(d2n * p, axis=1, keepdims=True) / sum_p
        return h, p / sum_p

    def body(_, state):
        beta, bmin, bmax = state
        h, _ = entropy(beta)
        too_high = h > log_u + tol          # entropy too high -> sharpen kernel
        bmin = jnp.where(too_high, beta, bmin)
        bmax = jnp.where(too_high, bmax, beta)
        up = jnp.where(jnp.isinf(bmax), beta * 2.0, 0.5 * (beta + bmax))
        down = jnp.where(bmin <= 0.0, beta * 0.5, 0.5 * (beta + bmin))
        beta = jnp.where(too_high, up, down)
        return beta, bmin, bmax

    beta0 = jnp.ones((n, 1), dtype)
    state = (beta0, jnp.zeros((n, 1), dtype), jnp.full((n, 1), inf))
    beta, _, _ = jax.lax.fori_loop(0, iters, body, state)
    _, cond_p = entropy(beta)
    return cond_p, (beta / scale)[:, 0]


def binary_search_perplexity_chunked(
    d2: jax.Array,
    perplexity: float,
    chunk_size: int,
    iters: int = 64,
    tol: float = 1e-5,
    impl: str = "xla",
):
    """Row-chunked :func:`binary_search_perplexity` — the million-point form.

    The bisection is independent per row (every reduction in the search is
    a row reduction), so chunking over the point axis is exact: each
    ``[chunk_size, K]`` slice runs the full search and results are
    concatenated.  Live transients are bounded by the chunk — the whole-
    array form keeps several ``[N, K]`` temporaries per bisection step —
    and every chunk reuses one compiled program: the last, non-dividing
    chunk is padded back up to ``chunk_size`` (pad rows cost compute but
    are sliced off, and a retrace per ragged tail shape is avoided).

    Matches the unchunked search to float tolerance for every chunk size
    (parity-tested in tests/test_chunked.py).
    """
    chunk = int(chunk_size)
    if chunk <= 0:
        raise ValueError(f"chunk_size={chunk_size} must be >= 1")
    n = d2.shape[0]
    if chunk >= n:
        return binary_search_perplexity(d2, perplexity, iters, tol, impl)
    ps, betas = [], []
    for start in range(0, n, chunk):
        blk = jax.lax.dynamic_slice_in_dim(d2, start, min(chunk, n - start))
        pad = chunk - blk.shape[0]
        if pad:
            # pad rows of ones: a flat row whose search converges instantly
            blk = jnp.pad(blk, ((0, pad), (0, 0)), constant_values=1.0)
        cp, beta = binary_search_perplexity(blk, perplexity, iters, tol, impl)
        ps.append(cp[: chunk - pad])
        betas.append(beta[: chunk - pad])
    return jnp.concatenate(ps, axis=0), jnp.concatenate(betas, axis=0)


def perplexity_of(cond_p: jax.Array) -> jax.Array:
    """exp(H) of each row — used by tests to verify the search converged."""
    h = -jnp.sum(jnp.where(cond_p > 0, cond_p * jnp.log(jnp.maximum(cond_p, 1e-30)), 0.0), axis=1)
    return jnp.exp(h)
