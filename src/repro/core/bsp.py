"""Binary Search Perplexity (paper §3.2), TPU formulation.

Prior CPU implementations were single-threaded; the paper multithreads the
per-point search with Numba prange.  Here every point's bisection runs in a
single branch-free vectorized loop over the whole point axis — "as many
threads as points".  The search variable is beta_i = 1 / (2 sigma_i^2),
matching scikit-learn's `_binary_search_perplexity`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("iters",))
def binary_search_perplexity(
    d2: jax.Array,
    perplexity: float,
    iters: int = 64,
    tol: float = 1e-5,
):
    """Conditional similarities p_{j|i} with per-row perplexity == target.

    d2 : [N, K] squared distances to the K nearest neighbors (self excluded)
    Returns (cond_p [N, K], beta [N]).
    """
    dtype = d2.dtype
    n = d2.shape[0]
    log_u = jnp.asarray(jnp.log(perplexity), dtype)
    inf = jnp.asarray(jnp.inf, dtype)

    # conditioning guards (the paper computes in float64; float32 needs both):
    # 1. shift by the row min — p_{j|i} is shift-invariant and exp(0)=1 keeps
    #    the nearest neighbor from underflowing at large beta;
    # 2. scale by the row mean so beta ~ O(1) across datasets.
    d2s = d2 - jnp.min(d2, axis=1, keepdims=True)
    scale = jnp.maximum(jnp.mean(d2s, axis=1, keepdims=True), jnp.asarray(1e-30, dtype))
    d2n = d2s / scale

    def entropy(beta):
        # beta: [N,1]
        p = jnp.exp(-d2n * beta)
        sum_p = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
        h = jnp.log(sum_p) + beta * jnp.sum(d2n * p, axis=1, keepdims=True) / sum_p
        return h, p / sum_p

    def body(_, state):
        beta, bmin, bmax = state
        h, _ = entropy(beta)
        too_high = h > log_u + tol          # entropy too high -> sharpen kernel
        bmin = jnp.where(too_high, beta, bmin)
        bmax = jnp.where(too_high, bmax, beta)
        up = jnp.where(jnp.isinf(bmax), beta * 2.0, 0.5 * (beta + bmax))
        down = jnp.where(bmin <= 0.0, beta * 0.5, 0.5 * (beta + bmin))
        beta = jnp.where(too_high, up, down)
        return beta, bmin, bmax

    beta0 = jnp.ones((n, 1), dtype)
    state = (beta0, jnp.zeros((n, 1), dtype), jnp.full((n, 1), inf))
    beta, _, _ = jax.lax.fori_loop(0, iters, body, state)
    _, cond_p = entropy(beta)
    return cond_p, (beta / scale)[:, 0]


def perplexity_of(cond_p: jax.Array) -> jax.Array:
    """exp(H) of each row — used by tests to verify the search converged."""
    h = -jnp.sum(jnp.where(cond_p > 0, cond_p * jnp.log(jnp.maximum(cond_p, 1e-30)), 0.0), axis=1)
    return jnp.exp(h)
