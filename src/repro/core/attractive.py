"""Attractive force (paper §3.6, Algorithm 2), TPU formulation.

The paper hand-vectorizes the CSR inner loop with AVX-512 (gather + FMA) and
adds software prefetch for the pseudo-random y_j reads.  On TPU:

* KNN yields exactly K = floor(3u) neighbors per point, so the sparse P is a
  *regular* [N, W] ELL layout — no ragged CSR indirection at all;
* the y[cols] gather is one fused XLA gather (TPU has a hardware gather path;
  Pallas double-buffering plays the role of software prefetch);
* the 10-FLOP epilogue is `kernels/attractive_kernel.py` when enabled.

Two equivalent formulations are provided:

``attractive_forces_ell``   — Algorithm 2 verbatim over a symmetric ELL matrix
                              (rows hold the full symmetric p_ij values).
``attractive_forces_edges`` — scatter/segment-sum over the 2NK directed-edge
                              list; exactly symmetric by construction and
                              fully jittable without host preprocessing (used
                              by the distributed path).

Both also return sum_ij p_ij * log(1 + d_ij^2), the attractive half of the
KL-divergence estimate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attractive_forces_ell(y: jax.Array, cols: jax.Array, vals: jax.Array):
    """Algorithm 2: per-row gather + FMA over the symmetric ELL matrix.

    y    : [N, 2]      embedding points
    cols : [N, W] int  neighbor indices (padding: col = row index)
    vals : [N, W]      symmetric p_ij (already / 2N; padding: 0)

    Returns (force [N,2], kl_attr scalar).
    """
    yj = y[cols]                                   # [N, W, 2] one big gather
    diff = y[:, None, :] - yj
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = vals / (1.0 + d2)                         # p_ij * (1+d^2)^-1
    force = jnp.sum(pq[..., None] * diff, axis=1)  # [N, 2]
    kl_attr = jnp.sum(vals * jnp.log1p(d2))
    return force, kl_attr


def attractive_forces_ell_components(y: jax.Array, cols: jax.Array, vals: jax.Array):
    """Algorithm 2 in structure-of-arrays form (§Perf hillclimb).

    The [N, W, 2] interleaved layout of ``attractive_forces_ell`` loads x/y
    components at stride 2, which defeats both AVX and VPU lane vectorization;
    gathering each coordinate into its own [N, W] plane keeps every op unit
    stride.  Numerically identical (tested).
    """
    yx, yy = y[:, 0], y[:, 1]
    gx = yx[cols]                                  # [N, W] unit-stride planes
    gy = yy[cols]
    dx = yx[:, None] - gx
    dy = yy[:, None] - gy
    d2 = dx * dx + dy * dy
    pq = vals / (1.0 + d2)
    fx = jnp.sum(pq * dx, axis=1)
    fy = jnp.sum(pq * dy, axis=1)
    kl_attr = jnp.sum(vals * jnp.log1p(d2))
    return jnp.stack([fx, fy], axis=1), kl_attr


def attractive_forces_ell_blocked(y: jax.Array, cols: jax.Array, vals: jax.Array,
                                  block: int = 512):
    """Algorithm 2, cache-blocked (§Perf hillclimb — the winning variant).

    The fully vectorized forms materialize [N, W] planes (tens of MB at
    N=20k, W=90) that thrash L2; the per-row loop has a tiny working set but
    no lane batching.  Blocking rows at `block` keeps the gather working set
    (~block*W floats) cache-resident while every op inside the block stays
    vectorized — the same SIMD+locality combination as the paper's AVX-512 +
    prefetch attractive kernel.  Measured 4.7x over the unblocked vector
    form and 2.3x over the row loop at N=20k (EXPERIMENTS.md §Perf).
    """
    n, w = cols.shape
    pad = (-n) % block
    cols_p = jnp.pad(cols, ((0, pad), (0, 0)))
    vals_p = jnp.pad(vals, ((0, pad), (0, 0)))
    yx, yy = y[:, 0], y[:, 1]
    x0_p = jnp.pad(yx, (0, pad))
    y0_p = jnp.pad(yy, (0, pad))
    nb = (n + pad) // block

    def one(args):
        cb, vb, x0, y0 = args
        gx = yx[cb]
        gy = yy[cb]
        dx = x0[:, None] - gx
        dy = y0[:, None] - gy
        d2 = dx * dx + dy * dy
        pq = vb / (1.0 + d2)
        return jnp.sum(pq * dx, 1), jnp.sum(pq * dy, 1), jnp.sum(vb * jnp.log1p(d2))

    shape = lambda a: a.reshape(nb, block, *a.shape[1:])
    fx, fy, kl = jax.lax.map(one, (shape(cols_p), shape(vals_p), shape(x0_p), shape(y0_p)))
    force = jnp.stack([fx.reshape(-1)[:n], fy.reshape(-1)[:n]], axis=1)
    return force, jnp.sum(kl)


# Single dispatch table for the ELL-layout variants — shared by bh_gradient
# and the api backends so a new implementation is registered exactly once.
ELL_IMPLS = {
    "ell": attractive_forces_ell,
    "components": attractive_forces_ell_components,
    "blocked": attractive_forces_ell_blocked,
}


def ell_impl(name: str):
    """Look up an ELL attractive kernel by name ('edges' is not an ELL impl)."""
    try:
        return ELL_IMPLS[name]
    except KeyError:
        raise ValueError(
            f"unknown attractive_impl {name!r}; ELL variants: "
            f"{', '.join(sorted(ELL_IMPLS))} (or 'edges' with an edge list)"
        ) from None


def attractive_forces_frozen(y: jax.Array, nbr_y: jax.Array, p: jax.Array):
    """Attractive force of free points against *frozen* neighbor coordinates.

    The out-of-sample kernel (FIt-SNE / t-SNE-CUDA style ``transform``):
    each new point ``y [M, 2]`` descends toward its k nearest *fitted*
    points, whose embedding coordinates ``nbr_y [M, K, 2]`` never move, with
    row-normalized similarities ``p [M, K]`` (padding: 0).  Rows are fully
    independent — no cross-point interaction — so the step is embarrassingly
    data-parallel and batches of unrelated requests share one program.

    Returns (force [M, 2], kl_attr [M] — per-point sum p log(1 + d²)).
    """
    diff = y[:, None, :] - nbr_y
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = p / (1.0 + d2)
    force = jnp.sum(pq[..., None] * diff, axis=1)
    kl_attr = jnp.sum(p * jnp.log1p(d2), axis=1)
    return force, kl_attr


def attractive_forces_edges(y: jax.Array, src: jax.Array, dst: jax.Array, w: jax.Array):
    """Symmetric attractive force from the directed edge list.

    Each directed KNN edge (i -> j, w = p_{j|i} / 2N) contributes
    f = w * (1+d^2)^-1 (y_i - y_j) to F_i and -f to F_j; summing over all NK
    directed edges yields exactly  sum_j p_ij (1+d^2)^-1 (y_i - y_j)  with
    p_ij = (p_{j|i} + p_{i|j}) / 2N.  Scatter-add = segment_sum (TPU native).
    """
    n = y.shape[0]
    ys, yd = y[src], y[dst]
    diff = ys - yd
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = w / (1.0 + d2)
    f = pq[:, None] * diff
    force = jnp.zeros_like(y)
    force = force.at[src].add(f)
    force = force.at[dst].add(-f)
    # each ordered pair (i,j) and (j,i) shares d^2: the directed edge carries
    # its w to both, hence the factor 2.
    kl_attr = 2.0 * jnp.sum(w * jnp.log1p(d2))
    return force, kl_attr
