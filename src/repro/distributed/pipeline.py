"""GPipe-style pipeline parallelism over a "pipe" mesh axis (shard_map).

The stage function is replicated code; stage-local weights are sharded over
the pipe axis (leading dim = stage). Microbatches stream through stages via
``collective_permute``; the classic 1F1B-ish schedule is flattened into
n_micro + n_stages - 1 ticks of a ``lax.scan``, so the whole pipeline is a
single SPMD program (bubble fraction = (S-1)/(M+S-1), reported by
``pipeline_bubble``). Used as an optional wrapper for very deep stacks
where FSDP+TP alone would not fit; unit-tested on forced host devices
(tests/test_pipeline.py) against the sequential reference.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_bubble(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipelined(stage_fn: Callable, mesh, *, axis: str = "pipe", n_micro: int):
    """Wrap ``stage_fn(stage_params, x) -> x`` into a pipelined apply.

    Returns ``apply(stacked_params, batch)`` where ``stacked_params`` has a
    leading [n_stages, ...] axis (sharded over ``axis``) and ``batch`` is
    [n_micro * micro_b, ...] (replicated across the pipe axis; stage 0
    feeds, the last stage's outputs are collected and re-assembled).
    """
    n_stages = mesh.shape[axis]

    def body(params_local, batch):
        # params_local: [1, ...] this stage's weights; batch replicated
        sp = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        micro = batch.reshape(n_micro, -1, *batch.shape[1:])
        mb_shape = micro.shape[1:]
        ticks = n_micro + n_stages - 1
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others use the buffer
            inject = jnp.where(t < n_micro, jnp.minimum(t, n_micro - 1), 0)
            x_in = jnp.where(stage == 0, micro[inject], buf)
            y = stage_fn(sp, x_in)
            # only compute validity: stage s works on micro (t - s)
            mid = t - stage
            valid = (mid >= 0) & (mid < n_micro)
            y = jnp.where(valid, y, buf)
            # last stage records its finished microbatch
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = (stage == n_stages - 1) & valid
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record, y, outs[out_idx]), out_idx, 0)
            # stream activations forward along the ring
            buf = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf, outs), None

        buf0 = jnp.zeros(mb_shape, batch.dtype)
        outs0 = jnp.zeros((n_micro, *mb_shape), batch.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # every stage returns `outs`, but only the last stage's is real:
        # broadcast it back with a psum of the masked tensor
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(-1, *batch.shape[1:])

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
