"""Fault-tolerance harness: crash/restart drills + straggler semantics.

``run_with_restarts`` executes a Trainer run, catching (injected or real)
failures and restarting from the last checkpoint up to ``max_restarts``
times — the single-process analogue of a cluster supervisor respawning a
failed job.  Determinism of the data pipeline (pure function of step) plus
checkpoint atomicity gives bit-exact resumption, asserted in tests.

Straggler mitigation at the JAX/SPMD level is architectural rather than
imperative: steps are globally synchronous, so the framework's levers are
(a) deterministic replay makes *restart* cheap (slow/failed host -> respawn
and rejoin at the last checkpoint), (b) checkpoint cadence bounds lost
work, and (c) `HeartbeatMonitor` is the detection hook a launcher polls to
decide eviction.  This module implements (a)+(b)+(c); backup-worker
scheduling lives in the cluster launcher, outside a single process.
"""
from __future__ import annotations

import time
from typing import Callable

from repro.train.trainer import SimulatedFailure, Trainer


class HeartbeatMonitor:
    """Step-scoped heartbeats: a launcher evicts ranks whose last beat is
    older than ``timeout_s`` (simulated single-process version)."""

    def __init__(self, n_ranks: int, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last_beat = {r: time.monotonic() for r in range(n_ranks)}

    def beat(self, rank: int):
        self.last_beat[rank] = time.monotonic()

    def dead_ranks(self) -> list[int]:
        now = time.monotonic()
        return [r for r, t in self.last_beat.items() if now - t > self.timeout_s]


def run_with_restarts(make_trainer: Callable[[], Trainer], seed: int = 0,
                      max_restarts: int = 3):
    """Run training to completion across simulated failures.

    Each restart constructs a fresh Trainer (fresh process analogue) that
    restores from the newest checkpoint. Returns (params, opt, steps, n_failures).
    """
    failures = 0
    while True:
        trainer = make_trainer()
        try:
            params, opt_state, steps = trainer.run(seed=seed)
            return params, opt_state, steps, failures
        except SimulatedFailure:
            failures += 1
            if failures > max_restarts:
                raise
            # a real supervisor would also re-provision hardware here
            trainer.tcfg.fail_at_step = None
