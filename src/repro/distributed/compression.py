"""Gradient compression with error feedback (distributed-optimization trick).

int8 uniform quantization per leaf (scale = max|g| / 127) applied to the
gradients before the (conceptual) cross-replica reduction, with the
quantization residual carried to the next step (error feedback, Seide et
al. 2014 / Karimireddy et al. 2019) so the bias vanishes in expectation.

Two entry points:
  * ``compress_grads``      — pure pytree transform used by the trainer;
  * ``compressed_psum``     — shard_map building block: quantize -> int32
                              psum -> dequantize (8x fewer bytes on the
                              wire than an f32 all-reduce).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any


def init_error_feedback(params) -> EFState:
    return EFState(residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState):
    """Returns (compressed-and-decompressed grads, new EF state)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize(g32)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, ef.residual)
    tup = lambda x: isinstance(x, tuple)
    new_g = jax.tree.map(lambda o: o[0], out, is_leaf=tup)
    new_r = jax.tree.map(lambda o: o[1], out, is_leaf=tup)
    return new_g, EFState(residual=new_r)


def compressed_psum(x, axis_name: str):
    """int8-quantized psum for shard_map code paths.

    Each shard quantizes with its local scale; scales are maxed across the
    axis so dequantization is consistent, then int32-summed payloads move
    8x fewer bytes than f32.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    s = jax.lax.psum(q, axis_name)
    return s.astype(jnp.float32) * scale
