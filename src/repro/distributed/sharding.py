"""Logical-axis sharding rules (MaxText-style) + param sharding derivation.

Model code annotates activations/weights with *logical* axis names; the
active ``MeshRules`` maps them onto physical mesh axes.  With no active mesh
everything is a no-op, so the same model code runs CPU smoke tests, the
single-pod (data, model) mesh and the multi-pod (pod, data, model) mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),    # data parallel over pods x data axis
    "seq": None,                 # sequence replicated (activations)
    "kv_seq": "data",            # decode KV caches: sequence-sharded (SP)
    "latent_seq": None,          # MLA latent cache sequence axis (per-cell)
    "embed": None,               # d_model in activations: replicated
    "heads": "model",            # TP over attention heads
    "kv_heads": "model",
    "ffn": "model",              # TP over FFN hidden
    "vocab": "model",            # TP over vocab
    "experts": "model",          # EP shares the model axis
    "fsdp": ("pod", "data"),     # ZeRO-3 weight sharding axis
    "layers": None,              # scanned layer axis
}


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh
    rules: dict[str, Any]

    def axis(self, logical: str | None):
        if logical is None:
            return None
        phys = self.rules.get(logical, None)
        if phys is None:
            return None
        if isinstance(phys, str):
            return phys if phys in self.mesh.axis_names else None
        # tuple: keep only axes present in this mesh
        kept = tuple(a for a in phys if a in self.mesh.axis_names)
        return kept if kept else None

    def spec(self, *logical: str | None) -> P:
        return P(*(self.axis(a) for a in logical))

    def sharding(self, *logical: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def active_rules() -> MeshRules | None:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh | None, rules: dict[str, Any] | None = None):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = MeshRules(mesh, dict(rules or DEFAULT_RULES)) if mesh is not None else None
    try:
        yield _STATE.rules
    finally:
        _STATE.rules = prev


def logical(x: jax.Array, *names: str | None) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o mesh)."""
    r = active_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.sharding(*names))


def batch_axes() -> tuple[str, ...]:
    r = active_rules()
    if r is None:
        return ()
    ax = r.axis("batch")
    if ax is None:
        return ()
    return (ax,) if isinstance(ax, str) else tuple(ax)


# ---------------------------------------------------------------------------
# Parameter shardings by leaf-path pattern
# ---------------------------------------------------------------------------

# map (substring of the param path, ndim) -> logical axes; first match wins.
# paths look like "layers/attn/wq", "embed/embedding", "layers/mlp/experts/w1"
_PARAM_RULES: list[tuple[str, dict[int, tuple]]] = [
    ("embedding", {2: ("vocab", "fsdp")}),
    ("unembed", {2: ("fsdp", "vocab")}),
    ("experts", {3: ("experts", "fsdp", None), 4: (None, "experts", "fsdp", None)}),
    ("router", {2: ("fsdp", None), 3: (None, "fsdp", None)}),
    ("wq", {2: ("fsdp", "heads"), 3: (None, "fsdp", "heads")}),
    ("wk", {2: ("fsdp", "heads"), 3: (None, "fsdp", "heads")}),
    ("wv", {2: ("fsdp", "heads"), 3: (None, "fsdp", "heads")}),
    ("wo", {2: ("heads", "fsdp"), 3: (None, "heads", "fsdp")}),
    ("w_dkv", {2: ("fsdp", None), 3: (None, "fsdp", None)}),
    ("w_dq", {2: ("fsdp", None), 3: (None, "fsdp", None)}),
    ("w_uk", {3: (None, "fsdp", "heads"), 4: (None, None, "fsdp", "heads")}),
    ("w_uv", {3: (None, "fsdp", "heads"), 4: (None, None, "fsdp", "heads")}),
    ("w_uq", {2: ("fsdp", "heads"), 3: (None, "fsdp", "heads")}),
    ("w_krope", {2: ("fsdp", None), 3: (None, "fsdp", None)}),
    ("w1", {2: ("fsdp", "ffn"), 3: (None, "fsdp", "ffn")}),
    ("w3", {2: ("fsdp", "ffn"), 3: (None, "fsdp", "ffn")}),
    ("w2", {2: ("ffn", "fsdp"), 3: (None, "ffn", "fsdp")}),
    ("in_proj", {2: ("fsdp", "heads"), 3: (None, "fsdp", "heads")}),
    ("out_proj", {2: ("heads", "fsdp"), 3: (None, "heads", "fsdp")}),
    ("conv", {2: (None, "heads"), 3: (None, None, "heads")}),
]


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _spec_for_leaf(path: str, shape: tuple, rules: MeshRules) -> P:
    ndim = len(shape)
    for pat, by_ndim in _PARAM_RULES:
        if pat in path and ndim in by_ndim:
            spec = [rules.axis(a) for a in by_ndim[ndim]]
            # pjit *argument* shardings require exact divisibility; drop any
            # axis that does not divide its dim (e.g. whisper's 51865 vocab)
            spec = [a if shape[i] % _axis_size(rules.mesh, a) == 0 else None
                    for i, a in enumerate(spec)]
            return P(*spec)
    # norms / biases / scalars: replicated
    return P(*([None] * ndim))


def params_shardings(params, mesh: Mesh, rules: dict | None = None):
    """NamedSharding pytree for a param pytree (keyed by leaf path)."""
    mr = MeshRules(mesh, dict(rules or DEFAULT_RULES))

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return NamedSharding(mesh, _spec_for_leaf(pstr, tuple(leaf.shape), mr))

    return jax.tree_util.tree_map_with_path(visit, params)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
