"""Out-of-sample embedding subsystem: ``TSNE.transform`` + serving loop.

Two layers over a frozen fitted embedding:

* :mod:`repro.embed.transform` — the attractive-only descent that places new
  points among their k nearest *fitted* neighbors (one fixed-shape jitted
  step; batch driver with padding + per-point early stop);
* :mod:`repro.embed.service` — :class:`EmbeddingService`, the
  continuous-batching slot loop (adapted from ``repro.serve.engine``) that
  drains a queue of single-point transform requests against a per-dataset
  cache of fitted models, with per-request latency/step stats.
"""
from repro.embed.transform import (
    TransformConfig, TransformState, TransformStats, prepare_batch,
    transform_batch, transform_step,
)
from repro.embed.service import EmbeddingService, TransformRequest

__all__ = [
    "TransformConfig", "TransformState", "TransformStats",
    "prepare_batch", "transform_batch", "transform_step",
    "EmbeddingService", "TransformRequest",
]
