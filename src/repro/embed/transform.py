"""Out-of-sample t-SNE ``transform``: descend new points into a frozen fit.

The parametric-free extension FIt-SNE and UMAP deployments use: the fitted
embedding is a frozen reference; each new point finds its k nearest *fitted*
input points (through the neighbor backend's query index), gets
perplexity-calibrated similarities over exactly those k rows, and runs
attractive-only gradient descent against their — never-moving — embedding
coordinates.  No refit, no repulsion, no interaction between new points.

Everything funnels through ONE jitted step, :func:`transform_step`, whose
shapes are ``[B, K]`` with B and K fixed per caller:

* :func:`transform_batch` pads request batches to ``TransformConfig.
  batch_size`` rows, so arbitrary batch sizes reuse a single trace;
* the :class:`~repro.embed.service.EmbeddingService` calls the same step
  over its ``[slots, max_k]`` pool, refilling finished slots between steps.

``momentum`` is a traced operand (scalar for whole-batch schedules, ``[B]``
for the service's per-slot schedules), so schedule switches never retrace.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bsp
from repro.core.attractive import attractive_forces_frozen

# Trace-time probe: one count per distinct (shape, static-arg) compile of
# transform_step.  Tests assert ``RETRACE_PROBE.count`` does NOT grow across
# different batch payloads — the fixed-shape step really is traced once —
# and service telemetry reports it as ``recompiles.transform_step``.
RETRACE_PROBE = obs.RecompileProbe("transform_step")


@dataclasses.dataclass(frozen=True)
class TransformConfig:
    """Knobs of the attractive-only descent (defaults match FIt-SNE's
    late-phase optimizer scaled to per-row-normalized similarities)."""

    n_iter: int = 120                 # max descent iterations per point
    learning_rate: float = 0.5
    momentum_initial: float = 0.5
    momentum_final: float = 0.8
    momentum_switch_iter: int = 30
    min_gain: float = 0.01
    min_grad_norm: float = 1e-5       # per-point convergence threshold
    check_every: int = 10             # host-side convergence-check period
    batch_size: int = 128             # fixed jit batch width for transform()
    perplexity: float | None = None   # None = the fitted model's perplexity


class TransformState(NamedTuple):
    """Per-point descent state (all rows independent)."""
    y: jax.Array          # [B, 2] current coordinates
    velocity: jax.Array   # [B, 2]
    gains: jax.Array      # [B, 2]


class TransformStats(NamedTuple):
    """Per-point outcome of a transform batch (host-side numpy)."""
    n_steps: np.ndarray       # iterations until convergence (or n_iter cap)
    grad_norm: np.ndarray     # final per-point gradient norm
    kl_attr: np.ndarray       # final per-point sum p log(1 + d²)


@functools.partial(jax.jit, static_argnames=("lr", "min_gain"))
def transform_step(
    state: TransformState,
    p: jax.Array,           # [B, K] row-normalized similarities (pad rows: 0)
    nbr_y: jax.Array,       # [B, K, 2] frozen fitted coordinates
    active: jax.Array,      # [B] bool — frozen rows keep their coordinates
    momentum,               # scalar or [B]
    *,
    lr: float,
    min_gain: float,
):
    """One attractive-only descent step; returns (state, grad_norm [B],
    kl_attr [B]).  Same momentum/gains rule as the full optimizer."""
    RETRACE_PROBE.record(state.y.shape, p.shape, lr, min_gain)
    force, kl_attr = attractive_forces_frozen(state.y, nbr_y, p)
    grad = 4.0 * force
    grad_norm = jnp.linalg.norm(grad, axis=1)
    same_sign = (grad > 0) == (state.velocity > 0)
    gains = jnp.where(same_sign, state.gains * 0.8, state.gains + 0.2)
    gains = jnp.maximum(gains, min_gain)
    mom = jnp.asarray(momentum, state.y.dtype)
    velocity = mom[..., None] * state.velocity - lr * gains * grad
    y = jnp.where(active[:, None], state.y + velocity, state.y)
    return TransformState(y=y, velocity=velocity, gains=gains), grad_norm, kl_attr


def prepare_batch(
    x_new: jax.Array,
    index,                     # NeighborIndex over the fitted inputs
    y_ref: jax.Array,          # [N, 2] frozen fitted embedding
    k: int,
    perplexity: float,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Admission path: query + perplexity search + neighbor-weighted init.

    Returns ``(p [M, k], nbr_y [M, k, 2], y0 [M, 2])``.  ``y0`` is the
    p-weighted mean of the fitted neighbor coordinates — already inside the
    right cluster, so the descent only fine-tunes.
    """
    idx, d2 = index.query(x_new, k)
    # perplexity can't exceed the support size: k rows bound entropy at log k
    eff_perp = min(float(perplexity), max(1.0, 0.5 * k))
    p, _ = bsp.binary_search_perplexity(d2, eff_perp)
    nbr_y = jnp.asarray(y_ref)[idx]
    y0 = jnp.einsum("mk,mkc->mc", p, nbr_y)
    return p, nbr_y, y0


def transform_batch(
    x_new,
    index,
    y_ref,
    *,
    k: int,
    perplexity: float,
    config: TransformConfig = TransformConfig(),
    tracer: obs.Tracer | None = None,
) -> tuple[np.ndarray, TransformStats]:
    """Embed ``x_new [M, D]`` into the frozen fit; M is arbitrary.

    Chunks of ``config.batch_size`` rows (zero-padded) run through the single
    jitted :func:`transform_step`; each chunk stops early once every live
    point's gradient norm drops under ``min_grad_norm`` (checked every
    ``check_every`` iterations, like the full loop's convergence rule).

    When ``tracer`` (default: the process-global tracer) is enabled the call
    is one ``transform`` span with a ``transform.prepare`` (query +
    perplexity search) and ``transform.descend`` child per chunk.
    """
    if tracer is None:
        tracer = obs.get_tracer()
    x_new = jnp.asarray(x_new)
    m = int(x_new.shape[0])
    bs = config.batch_size
    out_y = np.zeros((m, 2), np.float32)
    out_steps = np.zeros(m, np.int32)
    out_gn = np.zeros(m, np.float32)
    out_kl = np.zeros(m, np.float32)

    batch_ctx = tracer.span("transform", m=m, k=k, batch_size=bs)
    batch_ctx.__enter__()
    for lo in range(0, m, bs):
        chunk = x_new[lo:lo + bs]
        c = int(chunk.shape[0])
        pad = bs - c
        with tracer.span("transform.prepare", rows=c) as sp_prep:
            p, nbr_y, y0 = prepare_batch(chunk, index, y_ref, k, perplexity)
            sp_prep.sync((p, y0))
        desc_ctx = tracer.span("transform.descend", rows=c)
        desc_ctx.__enter__()
        if pad:
            p = jnp.pad(p, ((0, pad), (0, 0)))
            nbr_y = jnp.pad(nbr_y, ((0, pad), (0, 0), (0, 0)))
            y0 = jnp.pad(y0, ((0, pad), (0, 0)))
        state = TransformState(
            y=y0, velocity=jnp.zeros_like(y0), gains=jnp.ones_like(y0)
        )
        valid = np.arange(bs) < c
        active_h = valid.copy()
        steps = np.zeros(bs, np.int32)
        gn_h = np.zeros(bs, np.float32)
        kl_h = np.zeros(bs, np.float32)
        it = 0
        for it in range(config.n_iter):
            mom = config.momentum_initial if it < config.momentum_switch_iter \
                else config.momentum_final
            state, gn, kl_attr = transform_step(
                state, p, nbr_y, jnp.asarray(active_h),
                jnp.asarray(mom, jnp.float32),
                lr=config.learning_rate, min_gain=config.min_gain,
            )
            if (it + 1) % config.check_every == 0 or it == config.n_iter - 1:
                gn_np = np.asarray(gn)
                kl_np = np.asarray(kl_attr)
                newly = active_h & (gn_np < config.min_grad_norm)
                steps[newly] = it + 1
                gn_h[active_h] = gn_np[active_h]
                kl_h[active_h] = kl_np[active_h]
                active_h = active_h & ~newly
                if not active_h.any():
                    break
        steps[active_h] = it + 1
        out_y[lo:lo + c] = np.asarray(state.y)[:c]
        out_steps[lo:lo + c] = steps[:c]
        out_gn[lo:lo + c] = gn_h[:c]
        out_kl[lo:lo + c] = kl_h[:c]
        desc_ctx.__exit__(None, None, None)
    batch_ctx.__exit__(None, None, None)

    return out_y, TransformStats(n_steps=out_steps, grad_norm=out_gn,
                                 kl_attr=out_kl)
