"""Continuous-batching t-SNE embedding service.

The vLLM-style slot loop from ``repro.serve.engine``, re-targeted from token
decoding to out-of-sample embedding: a fixed pool of ``slots`` transform
lanes steps through ONE jitted ``transform_step`` together; lanes whose
point converged (gradient norm under tolerance, or the step cap) retire to
``completed`` and are refilled from the request queue between steps.  Fitted
models are cached per dataset name, so a single service instance serves
concurrent transform traffic against many frozen embeddings — requests for
different datasets share the same step program, because each lane carries
its own frozen neighbor coordinates (gathered once at admission).

    service = EmbeddingService(slots=8)
    service.fit_dataset("digits", x_train, perplexity=12.0, n_iter=300)
    for i, x in enumerate(x_new):
        service.submit(TransformRequest(rid=i, dataset="digits", x=x))
    done = service.run()
    done[0].y, done[0].n_steps, done[0].latency_s

Smoke entry point (CI):  PYTHONPATH=src python -m repro.embed.service --smoke
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.embed.transform import (
    TransformConfig, TransformState, prepare_batch, transform_step,
)


@dataclasses.dataclass
class TransformRequest:
    """One new point to embed into a named frozen fit."""

    rid: int
    dataset: str
    x: np.ndarray                      # [D] input-space coordinates
    y: np.ndarray | None = None        # [2] result, set on completion
    n_steps: int = 0                   # descent iterations consumed
    grad_norm: float = float("nan")    # gradient norm at retirement
    done: bool = False
    submitted_at: float = 0.0
    started_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency_s(self) -> float:
        """Wall time from submit to completion (queueing included)."""
        return self.finished_at - self.submitted_at

    @property
    def service_s(self) -> float:
        """Wall time from slot admission to completion."""
        return self.finished_at - self.started_at


class EmbeddingService:
    """Fixed-slot continuous-batching server over cached fitted models.

    ``max_k`` bounds the neighbor width across all served datasets; a
    model fitted with more neighbors is truncated to its ``max_k`` nearest
    at query time (similarities renormalized by the perplexity search), so
    every lane fits the one compiled ``[slots, max_k]`` step.
    """

    def __init__(
        self,
        slots: int = 8,
        max_k: int = 96,
        config: TransformConfig = TransformConfig(),
        metrics: obs.MetricsRegistry | None = None,
        tracer: obs.Tracer | None = None,
    ):
        """``metrics`` (default: a private registry, exposed as
        ``self.metrics``) continuously records service telemetry:
        ``service.queue_depth`` / ``service.slot_occupancy`` gauges
        (refreshed every tick, high-water marks kept),
        ``service.latency_s`` / ``service.service_s`` / ``service.steps``
        histograms observed at request retirement, and ``service.ticks`` /
        ``service.completed`` counters.  ``tracer`` (default: the process
        global, a no-op unless enabled) spans each admission and engine
        tick."""
        if slots < 1:
            raise ValueError(f"slots={slots} must be >= 1")
        self.slots = slots
        self.max_k = max_k
        self.config = config
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        self.tracer = tracer if tracer is not None else obs.get_tracer()
        self._models: dict[str, object] = {}       # name -> fitted TSNE
        # `queue` and `completed` are the cross-thread surfaces (submit()
        # and stats() may run off the engine thread) and are guarded by
        # `_lock`; `active` / `_state` / `_steps` / `ticks` are engine-
        # thread-owned and deliberately unguarded.
        self._lock = threading.Lock()
        self.queue: deque[TransformRequest] = deque()
        self.active: list[TransformRequest | None] = [None] * slots
        self.completed: list[TransformRequest] = []
        self._steps = np.zeros(slots, np.int32)
        # pooled device-side state, [slots, ...] — one compile for the life
        # of the service regardless of which datasets the lanes serve
        self._state = TransformState(
            y=jnp.zeros((slots, 2), jnp.float32),
            velocity=jnp.zeros((slots, 2), jnp.float32),
            gains=jnp.ones((slots, 2), jnp.float32),
        )
        self._p = jnp.zeros((slots, max_k), jnp.float32)
        self._nbr_y = jnp.zeros((slots, max_k, 2), jnp.float32)
        self.ticks = 0

    # ------------------------------------------------------------ models --

    def add_model(self, name: str, model) -> None:
        """Cache a fitted :class:`~repro.api.estimator.TSNE` under ``name``."""
        if not hasattr(model, "embedding_"):
            raise ValueError(f"model {name!r} is not fitted")
        self._models[name] = model

    def fit_dataset(self, name: str, x, **tsne_kwargs):
        """Fit a fresh estimator on ``x`` and cache it under ``name``."""
        from repro.api.estimator import TSNE
        model = TSNE(**tsne_kwargs).fit(x)
        self.add_model(name, model)
        return model

    def load_model(self, name: str, path) -> None:
        """Cache a model persisted with ``TSNE.save`` (cross-process cache)."""
        from repro.api.estimator import TSNE
        self.add_model(name, TSNE.load(path))

    def models(self) -> tuple[str, ...]:
        return tuple(sorted(self._models))

    # ------------------------------------------------------------- queue --

    def submit(self, req: TransformRequest) -> None:
        if req.dataset not in self._models:
            raise ValueError(
                f"unknown dataset {req.dataset!r}; cached models: "
                f"{', '.join(self.models()) or '(none)'}"
            )
        req.submitted_at = time.perf_counter()
        with self._lock:
            self.queue.append(req)
            depth = len(self.queue)
        self.metrics.gauge("service.queue_depth").set(depth)

    def _admit(self, slot: int, req: TransformRequest) -> None:
        """Query + perplexity search + init for one request, into ``slot``."""
        model = self._models[req.dataset]
        k = min(model.query_k_, self.max_k)
        with self.tracer.span("service.admit", rid=req.rid,
                              dataset=req.dataset, slot=slot) as sp:
            p, nbr_y, y0 = prepare_batch(
                jnp.asarray(req.x, jnp.float32)[None], model.query_index_,
                model.embedding_, k, model.perplexity,
            )
            sp.sync((p, y0))
        p_row = np.zeros((self.max_k,), np.float32)
        p_row[:k] = np.asarray(p[0])
        nbr_row = np.zeros((self.max_k, 2), np.float32)
        nbr_row[:k] = np.asarray(nbr_y[0])
        self._p = self._p.at[slot].set(jnp.asarray(p_row))
        self._nbr_y = self._nbr_y.at[slot].set(jnp.asarray(nbr_row))
        self._state = TransformState(
            y=self._state.y.at[slot].set(y0[0]),
            velocity=self._state.velocity.at[slot].set(0.0),
            gains=self._state.gains.at[slot].set(1.0),
        )
        self._steps[slot] = 0
        req.started_at = time.perf_counter()
        self.active[slot] = req

    def _refill(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None:
                # pop under the lock, admit (slow: device work) outside it
                with self._lock:
                    if not self.queue:
                        break
                    req = self.queue.popleft()
                self._admit(s, req)

    # -------------------------------------------------------------- loop --

    def step(self) -> bool:
        """One engine tick: refill empty lanes, advance every active lane by
        one jitted descent step, retire converged/capped lanes.  Returns
        False once the pool and queue are both empty."""
        self._refill()
        active_mask = np.array([r is not None for r in self.active])
        m = self.metrics
        with self._lock:
            depth = len(self.queue)
        m.gauge("service.queue_depth").set(depth)
        m.gauge("service.slot_occupancy").set(int(active_mask.sum()))
        if not active_mask.any():
            return False
        cfg = self.config
        momentum = np.where(
            self._steps < cfg.momentum_switch_iter,
            cfg.momentum_initial, cfg.momentum_final,
        ).astype(np.float32)
        with self.tracer.span("service.tick", tick=self.ticks,
                              occupancy=int(active_mask.sum())) as sp:
            self._state, grad_norm, _ = transform_step(
                self._state, self._p, self._nbr_y,
                jnp.asarray(active_mask), jnp.asarray(momentum),
                lr=cfg.learning_rate, min_gain=cfg.min_gain,
            )
            sp.sync(grad_norm)
        self.ticks += 1
        m.counter("service.ticks").inc()
        gn = np.asarray(grad_norm)
        y_now = None
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self._steps[s] += 1
            if gn[s] < cfg.min_grad_norm or self._steps[s] >= cfg.n_iter:
                if y_now is None:
                    y_now = np.asarray(self._state.y)
                req.y = y_now[s].copy()
                req.n_steps = int(self._steps[s])
                req.grad_norm = float(gn[s])
                req.done = True
                req.finished_at = time.perf_counter()
                with self._lock:
                    self.completed.append(req)
                self.active[s] = None
                m.counter("service.completed").inc()
                m.histogram("service.latency_s").observe(req.latency_s)
                m.histogram("service.service_s").observe(req.service_s)
                m.histogram("service.steps").observe(req.n_steps)
        # post-retirement refresh so a drained pool reads occupancy 0
        m.gauge("service.slot_occupancy").set(
            sum(r is not None for r in self.active))
        return True

    def run(self, max_ticks: int = 100_000) -> list[TransformRequest]:
        """Drain the queue; returns the requests completed by this call."""
        with self._lock:
            n_done = len(self.completed)
        ticks = 0
        while ticks < max_ticks:
            with self._lock:
                pending = bool(self.queue)
            if not pending and all(r is None for r in self.active):
                break
            self.step()
            ticks += 1
        with self._lock:
            return self.completed[n_done:]

    # ------------------------------------------------------------- stats --

    def stats(self) -> dict:
        """Aggregate service telemetry, O(histogram window) per call.

        Latency / step quantiles come from the bounded ``service.latency_s``
        and ``service.steps`` histograms maintained at retirement (p50 / p95
        / p99 over the retained window; count / mean / max exact), instead
        of re-sorting every completed request on each call.  Queue-depth and
        slot-occupancy high-water marks come from the gauges.

        ``recompiles`` surfaces every ``recompiles.*`` probe counter (the
        jitted ``transform_step`` carries one), so compile churn — the
        runtime confirmation of a static RT1xx finding — is visible in the
        same snapshot as the latency it explains."""
        from repro.obs import get_metrics
        recompiles = get_metrics().counter_values("recompiles.")
        with self._lock:
            done = len(self.completed)
            queued = len(self.queue)
            datasets = sorted({r.dataset for r in self.completed})
        if not done:
            return dict(completed=0, ticks=self.ticks, recompiles=recompiles)
        lat = self.metrics.histogram("service.latency_s")
        steps = self.metrics.histogram("service.steps")
        occ = self.metrics.gauge("service.slot_occupancy")
        qd = self.metrics.gauge("service.queue_depth")
        return dict(
            completed=done,
            ticks=self.ticks,
            queued=queued,
            datasets=datasets,
            recompiles=recompiles,
            latency_s_mean=lat.mean,
            latency_s_p50=lat.percentile(50),
            latency_s_p95=lat.percentile(95),
            latency_s_p99=lat.percentile(99),
            latency_s_max=lat.max,
            steps_mean=steps.mean,
            steps_p95=steps.percentile(95),
            steps_max=int(steps.max),
            slot_occupancy_max=int(occ.max_value) if occ.n_sets else 0,
            queue_depth_max=int(qd.max_value) if qd.n_sets else 0,
        )


def _smoke(trace_path: str | None = None) -> None:
    """CI smoke: fit a small dataset, push requests through the queue.

    ``trace_path`` enables the process-global tracer for the whole run
    (fit + admissions + ticks) and writes the Chrome-trace JSON there."""
    from repro.data.datasets import make_dataset

    tracer = None
    if trace_path:
        tracer = obs.set_tracer(obs.Tracer())

    x, _ = make_dataset("digits", n=480)
    train, new = x[:400], x[400:432]
    service = EmbeddingService(slots=4, max_k=48)
    service.fit_dataset(
        "digits", train, perplexity=10.0, n_iter=150, kl_every=75,
        random_state=0,
    )
    for i, xi in enumerate(new):
        service.submit(TransformRequest(rid=i, dataset="digits", x=xi))
    t0 = time.perf_counter()
    done = service.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(new), f"{len(done)}/{len(new)} completed"
    assert all(r.done and r.y is not None and np.isfinite(r.y).all()
               for r in done)
    s = service.stats()
    print(
        f"embedding-service smoke OK: {s['completed']} requests through "
        f"{service.slots} slots in {wall:.1f}s ({s['ticks']} ticks, "
        f"mean {s['steps_mean']:.0f} steps, "
        f"p50/p95 latency {s['latency_s_p50'] * 1e3:.0f}/"
        f"{s['latency_s_p95'] * 1e3:.0f}ms, "
        f"occupancy<= {s['slot_occupancy_max']}, "
        f"queue<= {s['queue_depth_max']})"
    )
    if tracer is not None:
        tracer.to_chrome_trace(trace_path, process_name="embed.service")
        n_ev = len(tracer.spans)
        print(f"wrote Chrome trace ({n_ev} spans) to {trace_path}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fit a small dataset and drain a short queue (CI)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable tracing and write a Perfetto-loadable "
                         "Chrome-trace JSON of the smoke run to PATH")
    args = ap.parse_args()
    if args.smoke:
        _smoke(trace_path=args.trace)
    else:
        ap.error("this module is a library; run with --smoke for the CI check")
