"""Synthetic stand-ins for the paper's six benchmark datasets (§4.2).

The container is offline, so each dataset is generated with the *same size
and dimensionality* as the original and a planted cluster structure (a
Gaussian mixture in a low-dimensional latent space pushed through a random
linear map + noise) so t-SNE has real structure to find and KL-divergence
comparisons between implementations are meaningful.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int
    classes: int
    latent: int = 10


# size/dim-matched to §4.2 (mouse uses the paper's post-PCA 20 components)
SPECS = {
    "digits": DatasetSpec("digits", 1797, 64, 10),
    "mnist": DatasetSpec("mnist", 70000, 784, 10),
    "cifar10": DatasetSpec("cifar10", 60000, 3072, 10),
    "fashion_mnist": DatasetSpec("fashion_mnist", 70000, 784, 10),
    "svhn": DatasetSpec("svhn", 99289, 3072, 10),
    "mouse_1p3m": DatasetSpec("mouse_1p3m", 1291337, 20, 30, latent=20),
}


def make_dataset(name: str, n: int | None = None, seed: int = 0):
    """Returns (x [n, dim] float32, labels [n] int32)."""
    spec = SPECS[name]
    n = n or spec.n
    # crc32, not hash(): stable across processes regardless of PYTHONHASHSEED
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2**16)
    centers = rng.normal(size=(spec.classes, spec.latent)) * 4.0
    labels = rng.integers(0, spec.classes, size=n)
    latent = centers[labels] + rng.normal(size=(n, spec.latent))
    if spec.dim > spec.latent:
        proj = rng.normal(size=(spec.latent, spec.dim)) / np.sqrt(spec.latent)
        x = latent @ proj + 0.3 * rng.normal(size=(n, spec.dim))
    else:
        x = latent[:, : spec.dim]
    return x.astype(np.float32), labels.astype(np.int32)
