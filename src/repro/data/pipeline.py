"""Deterministic synthetic LM token pipeline.

Batches are a pure function of (seed, step) — after a crash/restart the
pipeline replays or skips to any step bit-exactly, which is what makes the
checkpoint/restart fault-tolerance story exact (tests/test_fault.py).
On a real cluster each data-parallel host would slice its shard of the
global batch by process_index; the host-level API is the same.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    extra: int = 1        # +1 token so train batches carry labels

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step & 0x7FFFFFFF]))
        tokens = rng.integers(
            0, self.vocab_size,
            size=(self.global_batch, self.seq_len + self.extra),
            dtype=np.int32)
        return {"tokens": tokens}


@dataclasses.dataclass(frozen=True)
class FrontendPipeline(TokenPipeline):
    """Adds stubbed modality inputs (vlm patches / audio frames)."""
    frontend_key: str = ""
    frontend_shape: tuple = ()
    dtype: str = "bfloat16"

    def batch(self, step: int) -> dict:
        out = super().batch(step)
        if self.frontend_key:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 1, step & 0x7FFFFFFF]))
            arr = rng.normal(size=(self.global_batch, *self.frontend_shape))
            out[self.frontend_key] = arr.astype(np.float32)
        return out
