"""Pallas TPU kernels for the FFT-repulsion interpolation spread/gather.

t-SNE-CUDA's profile shows the grid interpolation becoming the bottleneck
once the field solve is an FFT; ours said the same (scatter-add over [N,9]
taps lowers to serialized XLA scatters).  TPUs have no fast scatter at all,
so both directions are reformulated as *matmuls* over one-hot tap matrices,
exploiting that the 3x3 Lagrange stencil is separable:

    spread:  grid[a, b] = sum_i Wx[i, a] * ch[i] * Wy[i, b]
                        = (Wx * ch)^T @ Wy          -- one MXU matmul/channel
    gather:  phi[i]     = sum_{a,b} Wx[i, a] * pot[a, b] * Wy[i, b]
                        = rowsum((Wx @ pot) * Wy)   -- one MXU matmul/channel

where Wx/Wy are [TILE, G] with the 3 Lagrange weights placed at columns
base..base+2 (built in-register from a broadcasted iota — no gather/scatter
anywhere).  The node lattice G is padded to the 128-lane boundary and small
enough (<= ~256 for any practical n_boxes) that the whole grid block stays
VMEM-resident:

* spread — grid over point tiles, every step accumulates its tile's
  contribution into the same [C, G, G] output block (zero-initialized at
  step 0: the sequential-grid revisiting pattern);
* gather — grid over point tiles, the potential block rides along broadcast
  (index_map -> 0) and each step emits its [TILE, C] interpolated values.

Oracles: ``core/fft_repulsion.spread_to_grid`` / ``gather_from_grid``
(exact on planted node-centered points, allclose elsewhere — the matmul
changes only the float summation order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

P_ORDER = 3      # must match core/fft_repulsion.P_ORDER
TILE = 256
LANE = 128       # node-lattice padding boundary


def _onehot_taps(idx, w, g: int):
    """[T, g] matrix with w[t, tap] at column idx[t] + tap, else 0."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], g), 1)
    out = jnp.zeros((idx.shape[0], g), w.dtype)
    for tap in range(P_ORDER):
        out = out + jnp.where(cols == idx[:, None] + tap, w[:, tap][:, None], 0.0)
    return out


def _spread_kernel(base_ref, wx_ref, wy_ref, ch_ref, out_ref, *, n_ch: int):
    i = pl.program_id(0)
    base = base_ref[...]                 # [T, 2] int32
    ch = ch_ref[...]                     # [T, C]
    g = out_ref.shape[-1]
    w_x = _onehot_taps(base[:, 0], wx_ref[...], g)   # [T, G]
    w_y = _onehot_taps(base[:, 1], wy_ref[...], g)
    acc = jnp.stack([
        jax.lax.dot_general(
            w_x * ch[:, c][:, None], w_y,
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        ).astype(out_ref.dtype)
        for c in range(n_ch)
    ])                                   # [C, G, G]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(i > 0)
    def _accum():
        out_ref[...] += acc


def _gather_kernel(pot_ref, base_ref, wx_ref, wy_ref, out_ref, *, n_ch: int):
    pot = pot_ref[...]                   # [C, G, G]
    base = base_ref[...]
    g = pot.shape[-1]
    w_x = _onehot_taps(base[:, 0], wx_ref[...], g)   # [T, G]
    w_y = _onehot_taps(base[:, 1], wy_ref[...], g)
    phi = [
        jnp.sum(
            jax.lax.dot_general(
                w_x, pot[c],
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
            ).astype(out_ref.dtype) * w_y,
            axis=1,
        )
        for c in range(n_ch)
    ]
    out_ref[...] = jnp.stack(phi, axis=1)            # [T, C]


def _pad_points(base, wx, wy, extra=None):
    n = base.shape[0]
    n_pad = (n + TILE - 1) // TILE * TILE
    pad = n_pad - n
    out = [jnp.pad(base, ((0, pad), (0, 0))),
           jnp.pad(wx, ((0, pad), (0, 0))),          # zero weights: no-op rows
           jnp.pad(wy, ((0, pad), (0, 0)))]
    if extra is not None:
        out.append(jnp.pad(extra, ((0, pad), (0, 0))))
    return n_pad, out


@functools.partial(jax.jit, static_argnames=("nodes", "interpret"))
def spread_to_grid_pallas(base, wx, wy, charges, nodes: int, interpret: bool = True):
    """Same contract as ``core/fft_repulsion.spread_to_grid``."""
    c = charges.shape[1]
    g = (nodes + LANE - 1) // LANE * LANE
    n_pad, (basep, wxp, wyp, chp) = _pad_points(base, wx, wy, charges)
    grid = pl.pallas_call(
        functools.partial(_spread_kernel, n_ch=c),
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((TILE, P_ORDER), lambda i: (i, 0)),
            pl.BlockSpec((TILE, P_ORDER), lambda i: (i, 0)),
            pl.BlockSpec((TILE, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((c, g, g), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, g, g), charges.dtype),
        interpret=interpret,
    )(basep, wxp, wyp, chp)
    return jnp.transpose(grid, (1, 2, 0))[:nodes, :nodes, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_from_grid_pallas(pot, base, wx, wy, interpret: bool = True):
    """Same contract as ``core/fft_repulsion.gather_from_grid``."""
    nodes, _, c = pot.shape
    n = base.shape[0]
    g = (nodes + LANE - 1) // LANE * LANE
    potp = jnp.pad(jnp.transpose(pot, (2, 0, 1)),
                   ((0, 0), (0, g - nodes), (0, g - nodes)))
    n_pad, (basep, wxp, wyp) = _pad_points(base, wx, wy)
    phi = pl.pallas_call(
        functools.partial(_gather_kernel, n_ch=c),
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((c, g, g), lambda i: (0, 0, 0)),
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((TILE, P_ORDER), lambda i: (i, 0)),
            pl.BlockSpec((TILE, P_ORDER), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c), pot.dtype),
        interpret=interpret,
    )(potp, basep, wxp, wyp)
    return phi[:n]
