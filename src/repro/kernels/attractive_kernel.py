"""Pallas TPU kernel for the attractive-force inner loop (paper Algorithm 2).

The paper hand-vectorizes this loop with AVX-512 (gathers + FMA) and software
prefetch.  TPU adaptation: the pseudo-random y[cols] gather is issued as one
XLA gather *outside* the kernel (TPU's gather path; Pallas' double-buffered
grid pipeline plays the role of software prefetch), and this kernel fuses the
remaining ~10 FLOP/neighbor epilogue over VMEM row tiles:

    pq   = val / (1 + ||y_i - y_j||^2)
    F_i += pq * (y_i - y_j)            and   kl_i += val * log1p(d^2)

Inputs per grid step: y tile [T, 2], gathered neighbors [T, W, 2], values
[T, W]; outputs force [T, 2] and per-row KL partials [T].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256


def _attractive_kernel(y_ref, ynb_ref, val_ref, f_ref, kl_ref):
    y = y_ref[...]                       # [T, 2]
    ynb = ynb_ref[...]                   # [T, W, 2]
    val = val_ref[...]                   # [T, W]
    diff = y[:, None, :] - ynb
    d2 = jnp.sum(diff * diff, axis=-1)
    pq = val / (1.0 + d2)
    f_ref[...] = jnp.sum(pq[..., None] * diff, axis=1)
    kl_ref[...] = jnp.sum(val * jnp.log1p(d2), axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attractive_forces_ell_pallas(y, cols, vals, interpret: bool = True):
    n, w = cols.shape
    ynb = y[cols]                        # XLA gather (stays outside the kernel)
    n_pad = (n + TILE - 1) // TILE * TILE
    pad = n_pad - n
    yp = jnp.pad(y, ((0, pad), (0, 0)))
    ynbp = jnp.pad(ynb, ((0, pad), (0, 0), (0, 0)))
    valp = jnp.pad(vals, ((0, pad), (0, 0)))
    force, kl = pl.pallas_call(
        _attractive_kernel,
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((TILE, w, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 2), y.dtype),
            jax.ShapeDtypeStruct((n_pad,), y.dtype),
        ],
        interpret=interpret,
    )(yp, ynbp, valp)
    return force[:n], jnp.sum(kl[:n])
