"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These re-export the core implementations — the kernels must agree with the
library's own math to float tolerance across shape/dtype sweeps (see
tests/test_kernels.py).  The same pairings live in ``kernels/ops.KERNELS``
(registry-style dispatch); this module is the flat import surface the
parity tests and docs/KERNELS.md use.
"""
from __future__ import annotations

from repro.core._pairwise import pairwise_sq_dists  # noqa: F401
from repro.core.attractive import attractive_forces_ell  # noqa: F401
from repro.core.bsp import _binary_search_perplexity_xla as binary_search_perplexity  # noqa: F401
from repro.core.fft_repulsion import (  # noqa: F401
    gather_from_grid, interp_coords, spread_to_grid,
)
from repro.core.morton import morton_encode  # noqa: F401
