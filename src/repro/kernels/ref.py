"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These re-export the core implementations — the kernels must agree with the
library's own math to float tolerance across shape/dtype sweeps (see
tests/test_kernels.py).
"""
from __future__ import annotations

from repro.core._pairwise import pairwise_sq_dists  # noqa: F401
from repro.core.attractive import attractive_forces_ell  # noqa: F401
from repro.core.morton import morton_encode  # noqa: F401
