"""Pallas TPU kernel for Morton code formation (paper Algorithm 1).

The paper notes the compiler auto-vectorizes this loop with AVX; on TPU the
analogue is a VPU-resident elementwise kernel over point tiles.  One grid
step processes a [TILE, 2] block of embedding points held in VMEM and emits
[TILE] uint32 codes; the root-cell scalars ride along as a (1, 4) block
broadcast to every tile (index_map -> 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024


def _expand_bits(v):
    v = v & jnp.uint32(0x0000FFFF)
    v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
    v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & jnp.uint32(0x33333333)
    v = (v | (v << 1)) & jnp.uint32(0x55555555)
    return v


def _morton_kernel(y_ref, root_ref, out_ref, *, depth: int):
    y = y_ref[...]                       # [TILE, 2]
    y_root_x = root_ref[0, 0]
    y_root_y = root_ref[0, 1]
    scale = root_ref[0, 2]
    hi = jnp.asarray(float(2**depth) - 1.0, y.dtype)
    mx_f = jnp.clip((y[:, 0] - y_root_x) * scale, 0.0, hi)
    my_f = jnp.clip((y[:, 1] - y_root_y) * scale, 0.0, hi)
    mx = _expand_bits(mx_f.astype(jnp.uint32))
    my = _expand_bits(my_f.astype(jnp.uint32))
    code = mx | (my << 1)
    if depth < 16:
        code = code & jnp.uint32((1 << (2 * depth)) - 1)
    out_ref[...] = code


@functools.partial(jax.jit, static_argnames=("depth", "interpret"))
def morton_encode_pallas(y, cent, r_span, depth: int = 16, interpret: bool = True):
    n = y.shape[0]
    n_pad = (n + TILE - 1) // TILE * TILE
    yp = jnp.pad(y, ((0, n_pad - n), (0, 0)))
    y_root = cent - r_span
    scale = (2.0 ** (depth - 1)) / r_span
    root = jnp.stack([y_root[0], y_root[1], scale.astype(y.dtype), jnp.zeros((), y.dtype)])[None, :]
    out = pl.pallas_call(
        functools.partial(_morton_kernel, depth=depth),
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, 2), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(yp, root)
    return out[:n]
