"""Pallas TPU kernel for the fused perplexity binary search (paper §3.2).

The XLA formulation in ``core/bsp.py`` runs one ``fori_loop`` over the whole
[N, K] array: every bisection step is a separate pass over HBM (exp + two
reductions + the bounds update), so 64 iterations read the distance matrix
64 times.  Roofline says the step is memory-bound (~6 flops/byte of d2
traffic per iteration) — exactly the shape Pallas fixes: tile the point
axis, keep a [TILE, K] block of d2 resident in VMEM, and run the *entire*
per-row bisection (all iterations + the final normalization) in one grid
step.  d2 is read from HBM once instead of ``iters`` times.

Per grid step: d2 tile [T, K] in, scalar params (log-perplexity, tolerance)
broadcast as a (1, 4) block, outputs cond_p [T, K] and beta [T].  The math
matches ``core/bsp.binary_search_perplexity`` line for line (same
conditioning guards: row-min shift, row-mean scale) so the parity tests in
``tests/test_kernels.py`` can require allclose on both outputs.

Zero padding rows are harmless: d2 = 0 gives a constant entropy row whose
bisection diverges to a large-but-finite beta, and the wrapper slices the
padding off before returning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 256


def _bsp_kernel(d2_ref, par_ref, cond_ref, beta_ref, *, iters: int):
    d2 = d2_ref[...]                     # [T, K]
    dtype = d2.dtype
    log_u = par_ref[0, 0]
    tol = par_ref[0, 1]

    # conditioning guards, identical to the XLA reference: shift by the row
    # min (p_{j|i} is shift-invariant; exp(0)=1 keeps the nearest neighbor
    # alive at large beta) and scale by the row mean so beta ~ O(1).
    d2s = d2 - jnp.min(d2, axis=1, keepdims=True)
    scale = jnp.maximum(jnp.mean(d2s, axis=1, keepdims=True),
                        jnp.asarray(1e-30, dtype))
    d2n = d2s / scale

    def entropy(beta):
        p = jnp.exp(-d2n * beta)
        sum_p = jnp.maximum(jnp.sum(p, axis=1, keepdims=True), 1e-30)
        h = jnp.log(sum_p) + beta * jnp.sum(d2n * p, axis=1, keepdims=True) / sum_p
        return h, p, sum_p

    def body(_, state):
        beta, bmin, bmax = state
        h, _, _ = entropy(beta)
        too_high = h > log_u + tol       # entropy too high -> sharpen kernel
        bmin = jnp.where(too_high, beta, bmin)
        bmax = jnp.where(too_high, bmax, beta)
        up = jnp.where(jnp.isinf(bmax), beta * 2.0, 0.5 * (beta + bmax))
        down = jnp.where(bmin <= 0.0, beta * 0.5, 0.5 * (beta + bmin))
        beta = jnp.where(too_high, up, down)
        return beta, bmin, bmax

    t = d2.shape[0]
    state = (jnp.ones((t, 1), dtype), jnp.zeros((t, 1), dtype),
             jnp.full((t, 1), jnp.inf, dtype))
    beta, _, _ = jax.lax.fori_loop(0, iters, body, state)
    _, p, sum_p = entropy(beta)
    cond_ref[...] = p / sum_p
    beta_ref[...] = (beta / scale)[:, 0]


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def binary_search_perplexity_pallas(
    d2: jax.Array,
    perplexity,
    iters: int = 64,
    tol: float = 1e-5,
    interpret: bool = True,
):
    """Fused per-tile bisection; same contract as the ``core/bsp`` reference.

    d2 : [N, K] squared neighbor distances (self excluded)
    Returns (cond_p [N, K], beta [N]).
    """
    n, k = d2.shape
    dtype = d2.dtype
    n_pad = (n + TILE - 1) // TILE * TILE
    d2p = jnp.pad(d2, ((0, n_pad - n), (0, 0)))
    par = jnp.stack([
        jnp.log(jnp.asarray(perplexity, dtype)),
        jnp.asarray(tol, dtype),
        jnp.zeros((), dtype), jnp.zeros((), dtype),
    ])[None, :]
    cond_p, beta = pl.pallas_call(
        functools.partial(_bsp_kernel, iters=iters),
        grid=(n_pad // TILE,),
        in_specs=[
            pl.BlockSpec((TILE, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE, k), lambda i: (i, 0)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k), dtype),
            jax.ShapeDtypeStruct((n_pad,), dtype),
        ],
        interpret=interpret,
    )(d2p, par)
    return cond_p[:n], beta[:n]
