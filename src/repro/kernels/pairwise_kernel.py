"""Pallas TPU kernel for blocked pairwise squared distances — the KNN hot
loop (paper §3.1; daal4py's KNN is the one step the paper reuses, we build
it).

Output tile [TQ, TC] = |q|^2 + |c|^2 - 2 q c^T: one MXU matmul per tile plus
a rank-1 VPU epilogue.  Tiles are 128-aligned for the MXU; the feature dim D
stays resident per tile (t-SNE inputs are post-PCA, D <= ~1k, well inside
VMEM: 128x1024 f32 = 0.5 MB per operand block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TQ = 128
TC = 128


def _pairwise_kernel(q_ref, c_ref, qn_ref, cn_ref, out_ref):
    q = q_ref[...]                       # [TQ, D]
    c = c_ref[...]                       # [TC, D]
    dots = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                    # [TQ, TC] on the MXU
    out = qn_ref[...].reshape(-1, 1) + cn_ref[...].reshape(1, -1) - 2.0 * dots
    out_ref[...] = jnp.maximum(out, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_sq_dists_pallas(q, db, q_sqn=None, db_sqn=None, interpret: bool = True):
    nq, d = q.shape
    nc = db.shape[0]
    if q_sqn is None:
        q_sqn = jnp.sum(q * q, axis=1)
    if db_sqn is None:
        db_sqn = jnp.sum(db * db, axis=1)
    nq_pad = (nq + TQ - 1) // TQ * TQ
    nc_pad = (nc + TC - 1) // TC * TC
    qp = jnp.pad(q, ((0, nq_pad - nq), (0, 0)))
    cp = jnp.pad(db, ((0, nc_pad - nc), (0, 0)))
    qnp_ = jnp.pad(q_sqn, (0, nq_pad - nq))
    cnp_ = jnp.pad(db_sqn, (0, nc_pad - nc))
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(nq_pad // TQ, nc_pad // TC),
        in_specs=[
            pl.BlockSpec((TQ, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TC, d), lambda i, j: (j, 0)),
            pl.BlockSpec((TQ,), lambda i, j: (i,)),
            pl.BlockSpec((TC,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((TQ, TC), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq_pad, nc_pad), q.dtype),
        interpret=interpret,
    )(qp, cp, qnp_, cnp_)
    return out[:nq, :nc]
