"""Jitted public wrappers around the Pallas kernels + the kernel registry.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU v5e and are validated via the interpreter against the
pure-jnp oracles in ref.py).

``kernel_registry()`` / ``get_kernel()`` form the registry-style dispatch
table the core modules and the roofline tool share: one entry per
kernelized hot path, carrying the pure-jnp oracle (``ref``) and the Pallas
entry point (``pallas``).  Core
call sites (``core/bsp.py``, ``core/fft_repulsion.py``, ``bh_gradient``)
select an implementation from their config flag; ``benchmarks/roofline.py
--tsne`` walks this table to report which hot paths are kernelized and
which are still plain XLA.  See docs/KERNELS.md for the playbook.
"""
from __future__ import annotations

import jax

from repro.kernels.attractive_kernel import attractive_forces_ell_pallas
from repro.kernels.bsp_kernel import binary_search_perplexity_pallas
from repro.kernels.interp_kernel import (
    gather_from_grid_pallas, spread_to_grid_pallas,
)
from repro.kernels.morton_kernel import morton_encode_pallas
from repro.kernels.pairwise_kernel import pairwise_sq_dists_pallas

_INTERPRET = jax.default_backend() != "tpu"


def morton_encode(y, cent, r_span, depth: int = 16):
    return morton_encode_pallas(y, cent, r_span, depth=depth, interpret=_INTERPRET)


def pairwise_sq_dists(q, db, q_sqn=None, db_sqn=None):
    return pairwise_sq_dists_pallas(q, db, q_sqn, db_sqn, interpret=_INTERPRET)


def attractive_forces_ell(y, cols, vals):
    return attractive_forces_ell_pallas(y, cols, vals, interpret=_INTERPRET)


def binary_search_perplexity(d2, perplexity, iters: int = 64, tol: float = 1e-5):
    return binary_search_perplexity_pallas(
        d2, perplexity, iters=iters, tol=tol, interpret=_INTERPRET
    )


def fft_spread(base, wx, wy, charges, nodes: int):
    return spread_to_grid_pallas(base, wx, wy, charges, nodes,
                                 interpret=_INTERPRET)


def fft_gather(pot, base, wx, wy):
    return gather_from_grid_pallas(pot, base, wx, wy, interpret=_INTERPRET)


def kernel_registry() -> dict:
    """name -> dict(ref=oracle fn, pallas=interpret-aware wrapper, doc).

    Built lazily: the oracles live in ``repro.core`` which must not import
    at ``repro.kernels`` import time (core modules lazily import this module
    for their own dispatch).
    """
    from repro.core import _pairwise, attractive, bsp, fft_repulsion, morton
    return {
        "morton_encode": dict(
            ref=morton.morton_encode, pallas=morton_encode,
            doc="Algorithm 1: Morton code formation"),
        "pairwise_sq_dists": dict(
            ref=_pairwise.pairwise_sq_dists, pallas=pairwise_sq_dists,
            doc="KNN distance tile (MXU matmul + rank-1 epilogue)"),
        "attractive_ell": dict(
            ref=attractive.attractive_forces_ell, pallas=attractive_forces_ell,
            doc="Algorithm 2: attractive-force epilogue over ELL rows"),
        "bsp_search": dict(
            ref=bsp._binary_search_perplexity_xla, pallas=binary_search_perplexity,
            doc="§3.2: fused per-row perplexity bisection over [N, K]"),
        "fft_spread": dict(
            ref=fft_repulsion.spread_to_grid, pallas=fft_spread,
            doc="FFT repulsion: charge scatter onto the node lattice"),
        "fft_gather": dict(
            ref=fft_repulsion.gather_from_grid, pallas=fft_gather,
            doc="FFT repulsion: potential interpolation back at the points"),
    }


def get_kernel(name: str, impl: str = "pallas"):
    """Dispatch helper: the ``impl`` entry point of registered kernel ``name``."""
    table = kernel_registry()
    try:
        entry = table[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r} (registered: {', '.join(sorted(table))})"
        ) from None
    if impl not in ("ref", "pallas"):
        raise ValueError(f"impl must be 'ref' or 'pallas', got {impl!r}")
    return entry[impl]


def available_kernels() -> tuple[str, ...]:
    return tuple(sorted(kernel_registry()))
