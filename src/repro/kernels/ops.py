"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU v5e and are validated via the interpreter against the
pure-jnp oracles in ref.py).
"""
from __future__ import annotations

import jax

from repro.kernels.attractive_kernel import attractive_forces_ell_pallas
from repro.kernels.morton_kernel import morton_encode_pallas
from repro.kernels.pairwise_kernel import pairwise_sq_dists_pallas

_INTERPRET = jax.default_backend() != "tpu"


def morton_encode(y, cent, r_span, depth: int = 16):
    return morton_encode_pallas(y, cent, r_span, depth=depth, interpret=_INTERPRET)


def pairwise_sq_dists(q, db, q_sqn=None, db_sqn=None):
    return pairwise_sq_dists_pallas(q, db, q_sqn, db_sqn, interpret=_INTERPRET)


def attractive_forces_ell(y, cols, vals):
    return attractive_forces_ell_pallas(y, cols, vals, interpret=_INTERPRET)
