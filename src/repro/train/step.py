"""Generic train_step over any (arch, optimizer) pair — the function the
multi-pod dry-run lowers for the train_4k shapes.

Supports microbatch gradient accumulation (cfg.grad_accum): the global
batch is split into microbatches scanned sequentially, so live activations
scale with the microbatch while the optimizer sees the full-batch gradient.
Accumulation dtype follows cfg.optimizer_dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw


def make_train_step(model, opt_cfg: AdamWConfig, grad_shardings=None):
    """grad_shardings: optional NamedSharding pytree (the params shardings).
    Constraining the accumulator to the *param* shardings makes GSPMD
    reduce-scatter each microbatch's cotangents into the sharded buffer
    instead of all-reducing a replicated one — ZeRO gradient sharding
    (§Perf: cut the llama3-405b per-micro grad all-reduce)."""
    n_micro = max(1, model.cfg.grad_accum)
    acc_dtype = jnp.dtype(model.cfg.optimizer_dtype)

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, grad_shardings)

    def train_step(params, opt_state: AdamWState, batch):
        if n_micro == 1:
            (loss, metrics), grads = grads_of(params, batch)
            grads = _constrain(grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch)

            def body(acc, mb):
                (loss, metrics), g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: (a + gi.astype(acc_dtype) / n_micro), acc, _constrain(g))
                return acc, (loss, metrics)

            zeros = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params))
            grads, (losses, metricses) = jax.lax.scan(body, zeros, micro)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metricses)
        new_params, new_opt, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss_mean": loss}
        return new_params, new_opt, metrics

    return train_step


def make_opt_init(model, opt_cfg: AdamWConfig):
    def opt_init(params):
        return init_adamw(params, opt_cfg)

    return opt_init


def opt_config_for(cfg) -> AdamWConfig:
    return AdamWConfig(moment_dtype=cfg.optimizer_dtype, factored=cfg.optimizer_factored)
