"""Sharded, asynchronous, elastically-resharding checkpointing.

Design (fault-tolerance substrate, DESIGN.md §5):

* **Sharded**: each leaf is written as a separate ``.npy`` under a directory
  tree mirroring the pytree, with a manifest (leaf paths, shapes, dtypes,
  step).  On a real multi-host cluster each host writes only the shards it
  owns (addressable_shards); here the host holds everything, so we gather.
* **Asynchronous**: writes happen on a background thread — the train loop
  only blocks on the *previous* save (one outstanding snapshot), hiding
  checkpoint latency behind compute exactly like production async ckpt.
* **Atomic**: written to ``<dir>.tmp`` then renamed, so a crash mid-write
  never corrupts the latest checkpoint; restore picks the newest complete
  step directory.
* **Elastic resharding**: restore() takes the *target* shardings (any mesh
  shape) and uses jax.device_put per leaf — a checkpoint taken on a
  (16,16) mesh restores onto (2,16,16) or a single CPU device unchanged.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_EXTENDED_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": getattr(ml_dtypes, "float8_e4m3fn", None),
    "float8_e5m2": getattr(ml_dtypes, "float8_e5m2", None),
}


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "__".join(
            re.sub(r"[^A-Za-z0-9_.-]", "_", str(getattr(k, "key", getattr(k, "idx", k))))
            for k in path)
        out.append((name or "leaf", leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot on the caller thread, write asynchronously."""
        self.wait()                                   # one outstanding save
        named = [(n, np.asarray(l)) for n, l in _leaf_paths(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                for n, a in named
            ],
        }

        def write():
            tmp = self.dir / f"step_{step:010d}.tmp"
            final = self.dir / f"step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for n, a in named:
                np.save(tmp / f"{n}.npy", a)
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self._steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def _steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self._steps()
        return max(steps) if steps else None

    def restore(self, template: Any, step: int | None = None, shardings: Any = None):
        """Restore into the structure of ``template``; optionally reshard.

        ``shardings``: pytree of NamedSharding (or None leaves) matching
        template — enables elastic restore onto any mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        dtypes = {m["name"]: m["dtype"] for m in manifest["leaves"]}
        names = [n for n, _ in _leaf_paths(template)]
        leaves = []
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(names))
        for name, sh in zip(names, shard_leaves):
            arr = np.load(d / f"{name}.npy")
            want = dtypes.get(name)
            if want in _EXTENDED_DTYPES and arr.dtype.kind == "V":
                arr = arr.view(_EXTENDED_DTYPES[want])  # np.save stores as raw
            if sh is not None:
                arr = jax.device_put(arr, sh)
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves), step
