"""AdamW + global-norm clipping, built from scratch (no optax dependency).

Two optimizer-state compression knobs (both are what make the 405B/671B
train cells fit a v5e pod, and both are first-class "distributed
optimization tricks" of this framework):

* ``moment_dtype="bfloat16"`` — moments stored in bf16 (updates in f32);
* ``factored=True``          — Adafactor-style factored second moment for
  >=2D params: row/col running means instead of the full tensor
  (O(in+out) instead of O(in*out) state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    moment_dtype: str = "float32"
    factored: bool = False


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any        # full tensor, or {"row": ..., "col": ...} when factored


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)

    def vz(p):
        if cfg.factored and _factorable(p):
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, mdt)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        v=jax.tree.map(vz, params),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.learning_rate * warm


def _sumsq(leaf) -> jax.Array:
    """sum(x^2) in f32 without materializing a whole-stack f32 copy: big
    stacked leaves are reduced layer-slice by layer-slice."""
    if leaf.ndim >= 3 and leaf.shape[0] > 1 and leaf.size > 1_000_000:
        def body(i, acc):
            sl = jax.lax.dynamic_index_in_dim(leaf, i, 0, keepdims=False)
            return acc + jnp.sum(jnp.square(sl.astype(jnp.float32)))
        return jax.lax.fori_loop(0, leaf.shape[0], body, jnp.zeros((), jnp.float32))
    return jnp.sum(jnp.square(leaf.astype(jnp.float32)))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    total = jnp.zeros((), jnp.float32)
    for l in leaves:
        # serial dependency: one leaf's f32 transient alive at a time
        l, total = jax.lax.optimization_barrier((l, total))
        total = total + _sumsq(l)
    return jnp.sqrt(total)


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - b1**stepf
    bc2 = 1.0 - b2**stepf
    mdt = jnp.dtype(cfg.moment_dtype)
    is_v_leaf = lambda x: isinstance(x, dict) and set(x) == {"row", "col"}

    def upd_one(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        g2 = g * g
        if is_v_leaf(v):
            row = b2 * v["row"] + (1 - b2) * jnp.mean(g2, axis=-1)
            col = b2 * v["col"] + (1 - b2) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction (Shazeer & Stern 2018)
            vhat = (row[..., None] * col[..., None, :]
                    / jnp.maximum(jnp.mean(row, axis=-1)[..., None, None], 1e-30)) / bc2
            new_v = {"row": row, "col": col}
        else:
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g2
            vhat = v32 / bc2
            new_v = v32.astype(mdt)
        mhat = m32 / bc1
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), new_v)

    def upd_stacked(p, g, m, v):
        """Layer-sliced in-place update for scan-stacked leaves: f32
        transients stay one-layer-sized and the donated (p, m, v) buffers
        are updated via in-place dynamic-update-slice inside the loop."""
        factored = is_v_leaf(v)

        def body(i, carry):
            p, m, v = carry
            idx = lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)
            vi = {"row": idx(v["row"]), "col": idx(v["col"])} if factored else idx(v)
            np_, nm, nv = upd_one(idx(p), idx(g), idx(m), vi)
            put = lambda t, s: jax.lax.dynamic_update_index_in_dim(t, s, i, 0)
            p = put(p, np_)
            m = put(m, nm)
            if factored:
                v = {"row": put(v["row"], nv["row"]), "col": put(v["col"], nv["col"])}
            else:
                v = put(v, nv)
            return p, m, v

        return jax.lax.fori_loop(0, p.shape[0], body, (p, m, v))

    def upd(p, g, m, v):
        if p.ndim >= 3 and p.shape[0] > 1 and p.size > 1_000_000:
            return upd_stacked(p, g, m, v)
        return upd_one(p, g, m, v)

    # serialize per-leaf updates (barrier chain) so at most one leaf's f32
    # transients are live at a time
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves = treedef.flatten_up_to(state.m)
    v_leaves = treedef.flatten_up_to(state.v)
    order = sorted(range(len(p_leaves)), key=lambda i: -p_leaves[i].size)
    results: list = [None] * len(p_leaves)
    dep = jnp.zeros((), jnp.float32)
    for i in order:
        gi, di = jax.lax.optimization_barrier((g_leaves[i], dep))
        new_p, new_m_leaf, new_v_leaf = upd(p_leaves[i], gi, m_leaves[i], v_leaves[i])
        results[i] = (new_p, new_m_leaf, new_v_leaf)
        first = new_p if not isinstance(new_p, dict) else new_p["row"]
        dep = first.ravel()[0].astype(jnp.float32) + di
    new_params = jax.tree.unflatten(treedef, [r[0] for r in results])
    new_m = jax.tree.unflatten(treedef, [r[1] for r in results])
    new_v = jax.tree.unflatten(treedef, [r[2] for r in results])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
