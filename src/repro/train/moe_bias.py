"""Aux-loss-free MoE load balancing (DeepSeek-V3, arXiv:2408.15664).

The router bias is a *non-trainable* parameter adjusted from observed
expert load: overloaded experts get their selection bias decreased,
underloaded increased. Applied by the trainer between optimizer steps for
``router='sigmoid_bias'`` archs (the bias enters top-k selection only, not
the combine weights, so this is gradient-free).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def update_router_bias(params, expert_load, rate: float = 1e-3):
    """expert_load: [E] mean load (1.0 == perfectly balanced).

    Returns params with every ``router/bias`` leaf nudged by
    -rate * sign(load - 1) (stacked [L, E] biases accept [E] or [L, E] load).
    """

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if pstr.endswith("router/bias"):
            err = jnp.sign(expert_load.astype(jnp.float32) - 1.0)
            return (leaf.astype(jnp.float32) - rate * err).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)
