"""Training loop with checkpoint/restart fault tolerance.

Responsibilities:
  * deterministic resume: restore (params, opt) from the newest checkpoint
    and continue from that step — the data pipeline replays by step id, so
    a restarted run is bit-exact with an uninterrupted one (test_fault.py);
  * async sharded checkpoints every ``ckpt_every`` steps;
  * optional simulated failure injection (``fail_at_step``) for tests;
  * metrics log (python list + optional callback) — substrate, not a UI.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig
from repro.train.step import make_opt_init, make_train_step, opt_config_for


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclasses.dataclass
class TrainerConfig:
    n_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "runs/ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    fail_at_step: int | None = None


class Trainer:
    def __init__(self, model, pipeline, tcfg: TrainerConfig,
                 opt_cfg: AdamWConfig | None = None,
                 donate: bool = True):
        self.model = model
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or opt_config_for(model.cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        step_fn = make_train_step(model, self.opt_cfg)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1) if donate else ())
        self.opt_init = make_opt_init(model, self.opt_cfg)
        self.metrics_log: list[dict] = []

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = self.opt_init(params)
        return params, opt_state, 0

    def restore_or_init(self, seed: int = 0):
        params, opt_state, start = self.init_state(seed)
        latest = self.ckpt.latest_step()
        if latest is not None:
            (params, opt_state), step = self.ckpt.restore((params, opt_state))
            start = step
        return params, opt_state, start

    def run(self, seed: int = 0, callback: Callable[[int, dict], None] | None = None):
        params, opt_state, start = self.restore_or_init(seed)
        t0 = time.perf_counter()
        step = start
        for step in range(start, self.tcfg.n_steps):
            if self.tcfg.fail_at_step is not None and step == self.tcfg.fail_at_step:
                self.ckpt.wait()
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self.pipeline.batch(step)
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == self.tcfg.n_steps:
                self.ckpt.save(step + 1, (params, opt_state))
            if (step + 1) % self.tcfg.log_every == 0 or step + 1 == self.tcfg.n_steps:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()
                     if np.asarray(v).size == 1}
                m["step"] = step + 1
                m["wall_s"] = time.perf_counter() - t0
                self.metrics_log.append(m)
                if callback:
                    callback(step + 1, m)
        self.ckpt.wait()
        return params, opt_state, step + 1
